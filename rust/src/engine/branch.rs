//! Time-travel branching: fork a run at a snapshot, override the
//! fault or traffic streams from the fork point, and diff the two
//! timelines through the span ledger.
//!
//! A branch is a resumed [`ClusterEngine`] whose *static context* is
//! edited before the run continues: [`BranchOverrides::kill_chip`]
//! forces a chip drained from a cycle onward (the "what if chip k died
//! at C" counterfactual), [`BranchOverrides::rate_scale`] regenerates
//! the open-loop arrival tail from the fork point under a scaled rate
//! curve (the "what if demand doubled" counterfactual). Everything
//! before the fork is shared history — byte-identical by construction
//! — so [`first_divergence`] of the two span-ledger reports localizes
//! exactly when the counterfactual starts to matter. An **empty**
//! override set must reproduce the base run bit-for-bit; `repro
//! replay --branch` asserts that at runtime before trusting any diff.

use std::cmp::Reverse;

use crate::obs::attrib::{AuditReport, FaultEpisode};
use crate::serve::loadgen;

use super::command::{EV_CHIP_DRAIN, EV_CHIP_READMIT, EV_CLIENT_READY};
use super::engine::ClusterEngine;

/// What a branch changes from the fork point on. Parsed from a small
/// `[branch]` override file (see [`BranchOverrides::parse`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct BranchOverrides {
    /// Fork at this cycle (must name a snapshot boundary); `None`
    /// defers to the driver's `--from-cycle` / last-snapshot default.
    pub fork_cycle: Option<u64>,
    /// Force chip `.0` drained from cycle `.1` (clamped to the fork)
    /// to the end of the run.
    pub kill_chip: Option<(usize, u64)>,
    /// Regenerate the open-loop arrival tail under `curve.scaled(s)`.
    pub rate_scale: Option<f64>,
}

impl BranchOverrides {
    /// Does this override set change anything? An empty set is the
    /// identity branch — the replay driver uses it to verify the
    /// fork machinery against the base run byte-for-byte.
    pub fn is_empty(&self) -> bool {
        self.kill_chip.is_none() && self.rate_scale.is_none()
    }

    /// Parse an override file:
    ///
    /// ```text
    /// # what if chip 2 died mid-crowd?
    /// [branch]
    /// fork_cycle = 40000
    /// kill_chip  = 2 at 45000
    /// rate_scale = 2.0
    /// ```
    ///
    /// `#` starts a comment; every key is optional.
    pub fn parse(text: &str) -> Result<BranchOverrides, String> {
        let mut ov = BranchOverrides::default();
        let mut in_section = false;
        for (idx, raw) in text.lines().enumerate() {
            let ln = idx + 1;
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line == "[branch]" {
                in_section = true;
                continue;
            }
            if line.starts_with('[') {
                return Err(format!("line {ln}: unknown section `{line}`"));
            }
            if !in_section {
                return Err(format!("line {ln}: expected `[branch]` before keys"));
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {ln}: expected `key = value`"))?;
            let value = value.trim();
            match key.trim() {
                "fork_cycle" => {
                    let c: u64 = value
                        .parse()
                        .map_err(|_| format!("line {ln}: fork_cycle wants a cycle count"))?;
                    ov.fork_cycle = Some(c);
                }
                "kill_chip" => {
                    let (chip, at) = value
                        .split_once(" at ")
                        .ok_or_else(|| format!("line {ln}: kill_chip wants `<chip> at <cycle>`"))?;
                    let chip: usize = chip
                        .trim()
                        .parse()
                        .map_err(|_| format!("line {ln}: kill_chip wants a chip index"))?;
                    let at: u64 = at
                        .trim()
                        .parse()
                        .map_err(|_| format!("line {ln}: kill_chip wants a cycle count"))?;
                    ov.kill_chip = Some((chip, at));
                }
                "rate_scale" => {
                    let s: f64 = value
                        .parse()
                        .map_err(|_| format!("line {ln}: rate_scale wants a number"))?;
                    if !(s.is_finite() && s > 0.0) {
                        return Err(format!("line {ln}: rate_scale must be finite and positive"));
                    }
                    ov.rate_scale = Some(s);
                }
                k => return Err(format!("line {ln}: unknown key `{k}`")),
            }
        }
        Ok(ov)
    }
}

/// Apply `ov` to a just-resumed engine standing at the `fork` cycle
/// boundary. Edits the static context (lifecycle, arrival stream) and
/// the outstanding command set consistently; the apply-loop itself is
/// untouched, so a branched run obeys every invariant a normal run
/// does.
pub fn apply(eng: &mut ClusterEngine, ov: &BranchOverrides, fork: u64) -> Result<(), String> {
    if let Some(s) = ov.rate_scale {
        let Some(o) = eng.cfg.open_loop else {
            return Err("rate_scale needs an open-loop scenario".into());
        };
        // Drop every not-yet-offered arrival (in open mode all pending
        // ClientReady commands are future arrivals), regenerate the
        // stream under the scaled curve, and splice in its post-fork
        // tail. The offered prefix is shared history and stays.
        let kept: Vec<(u64, u8, u64)> = eng
            .heap
            .iter()
            .map(|r| r.0)
            .filter(|&(_, kind, _)| kind != EV_CLIENT_READY)
            .collect();
        eng.heap = kept.into_iter().map(Reverse).collect();
        eng.open_arrivals.truncate(eng.offered);
        let scaled = loadgen::open_arrivals(
            eng.cfg.seed,
            loadgen::OPEN_ARRIVAL_STREAM,
            &o.curve.scaled(s),
            o.horizon_cycles,
            eng.eval_n,
            o.max_arrivals,
        );
        for a in scaled.into_iter().filter(|a| a.cycle >= fork) {
            if eng.open_arrivals.len() >= o.max_arrivals {
                break; // the spec's request budget still bounds the branch
            }
            let idx = eng.open_arrivals.len() as u64;
            eng.heap.push(Reverse((a.cycle, EV_CLIENT_READY, idx)));
            eng.open_arrivals.push(a);
        }
    }
    if let Some((chip, at)) = ov.kill_chip {
        if chip >= eng.chips.len() {
            return Err(format!(
                "kill_chip {chip} out of range (fleet has {} chips)",
                eng.chips.len()
            ));
        }
        let at = at.max(fork);
        // Scheduled lifecycle wake-ups at or after the kill belong to
        // episodes the forced drain supersedes — drop them, then
        // schedule the forced drain itself.
        let kept: Vec<(u64, u8, u64)> = eng
            .heap
            .iter()
            .map(|r| r.0)
            .filter(|&(cycle, kind, key)| {
                !((kind == EV_CHIP_DRAIN || kind == EV_CHIP_READMIT)
                    && key == chip as u64
                    && cycle >= at)
            })
            .collect();
        eng.heap = kept.into_iter().map(Reverse).collect();
        eng.chips[chip].lifecycle.force_drain_from(at);
        eng.heap.push(Reverse((at, EV_CHIP_DRAIN, chip as u64)));
    }
    Ok(())
}

/// The cycle stamp where two episodes stop agreeing.
fn episode_candidate(a: &FaultEpisode, b: &FaultEpisode) -> u64 {
    if a.start_cycle != b.start_cycle {
        return a.start_cycle.min(b.start_cycle);
    }
    if a.end_cycle != b.end_cycle {
        return match (a.end_cycle, b.end_cycle) {
            (Some(x), Some(y)) => x.min(y),
            (Some(x), None) | (None, Some(x)) => x,
            (None, None) => a.start_cycle,
        };
    }
    a.start_cycle
}

/// Earliest cycle at which two span-ledger reports disagree — the
/// observable onset of a branch's counterfactual (`None`: the
/// timelines are identical through the ledger's lens). Spans are
/// compared in id order, episodes in (chip, start) order; for a
/// differing pair the candidate is the first cycle stamp that
/// disagrees, so shared pre-fork history never contributes.
pub fn first_divergence(base: &AuditReport, branch: &AuditReport) -> Option<u64> {
    let mut candidates: Vec<u64> = Vec::new();
    let n = base.spans.len().max(branch.spans.len());
    for i in 0..n {
        match (base.spans.get(i), branch.spans.get(i)) {
            (Some(a), Some(b)) if a == b => {}
            (Some(a), Some(b)) => {
                let c = if a.enqueue_cycle != b.enqueue_cycle {
                    a.enqueue_cycle.min(b.enqueue_cycle)
                } else if a.dispatch_cycle != b.dispatch_cycle {
                    a.dispatch_cycle.min(b.dispatch_cycle)
                } else if a.complete_cycle != b.complete_cycle {
                    a.complete_cycle.min(b.complete_cycle)
                } else {
                    // same stamps, different derived fields (chip,
                    // waits, reshards): the divergence is inside the
                    // span's lifetime
                    a.enqueue_cycle
                };
                candidates.push(c);
            }
            (Some(x), None) | (None, Some(x)) => candidates.push(x.enqueue_cycle),
            (None, None) => {}
        }
    }
    let n = base.episodes.len().max(branch.episodes.len());
    for i in 0..n {
        match (base.episodes.get(i), branch.episodes.get(i)) {
            (Some(a), Some(b)) if a == b => {}
            (Some(a), Some(b)) => candidates.push(episode_candidate(a, b)),
            (Some(x), None) | (None, Some(x)) => candidates.push(x.start_cycle),
            (None, None) => {}
        }
    }
    candidates.into_iter().min()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_files_parse_and_default_to_identity() {
        let ov = BranchOverrides::parse(
            "# counterfactual\n[branch]\nfork_cycle = 40000\nkill_chip = 2 at 45000\n\
             rate_scale = 2.0  # double demand\n",
        )
        .unwrap();
        assert_eq!(ov.fork_cycle, Some(40_000));
        assert_eq!(ov.kill_chip, Some((2, 45_000)));
        assert_eq!(ov.rate_scale, Some(2.0));
        assert!(!ov.is_empty());

        let empty = BranchOverrides::parse("[branch]\n# nothing overridden\n").unwrap();
        assert!(empty.is_empty());
        assert_eq!(BranchOverrides::parse("").unwrap(), BranchOverrides::default());
    }

    #[test]
    fn malformed_override_files_are_rejected_with_line_numbers() {
        for (text, needle) in [
            ("kill_chip = 1 at 5", "[branch]"),
            ("[branch]\nkill_chip = 1", "at"),
            ("[branch]\nrate_scale = -1", "positive"),
            ("[branch]\nrate_scale = nan", "positive"),
            ("[branch]\nwarp_factor = 9", "unknown key"),
            ("[other]\n", "unknown section"),
            ("[branch]\nfork_cycle", "key = value"),
        ] {
            let err = BranchOverrides::parse(text).unwrap_err();
            assert!(err.contains(needle), "{text:?} -> {err}");
            assert!(err.starts_with("line "), "{err}");
        }
    }

    #[test]
    fn identical_reports_have_no_divergence() {
        let empty = AuditReport { spans: vec![], episodes: vec![], chips: vec![], horizon: 0 };
        assert_eq!(first_divergence(&empty, &empty), None);
    }

    #[test]
    fn episode_candidates_prefer_the_first_differing_stamp() {
        let base = FaultEpisode {
            chip: 0,
            start_cycle: 100,
            end_cycle: Some(500),
            faults: 1,
            remaps: 1,
            remap_latency_total: 10,
            remap_latency_max: 10,
            requests_stalled: 0,
            cycles_lost: 0,
            dip_requests: 0,
            dip_correct: 0,
        };
        let mut shifted = base.clone();
        shifted.start_cycle = 300;
        assert_eq!(episode_candidate(&base, &shifted), 100);
        let mut extended = base.clone();
        extended.end_cycle = None;
        assert_eq!(episode_candidate(&base, &extended), 500, "open end diverges at the close");
    }
}
