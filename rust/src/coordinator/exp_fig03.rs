//! Fig. 3 (§III-B motivation): fully functional probability of the 2-D
//! computing array protected with the *classical* schemes (RR, CR, DR)
//! under the random fault model — the figure that motivates HyCA by
//! showing the classical spares cannot absorb ~10 faults even with 32
//! spares available.

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::montecarlo::FaultModel;
use crate::redundancy::{cr::ColumnRedundancy, dr::DiagonalRedundancy, rr::RowRedundancy};
use crate::redundancy::{evaluate_scheme, Scheme};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig03;

impl Experiment for Fig03 {
    fn id(&self) -> &'static str {
        "fig3"
    }

    fn title(&self) -> &'static str {
        "Fully functional probability of RR/CR/DR, 32x32 array, random faults"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let dims = Dims::PAPER;
        let schemes: Vec<Box<dyn Scheme>> = vec![
            Box::new(RowRedundancy::default()),
            Box::new(ColumnRedundancy::default()),
            Box::new(DiagonalRedundancy),
        ];
        let mut t = Table::new(
            self.title(),
            &["PER(%)", "mean_faults", "RR", "CR", "DR"],
        );
        for per in opts.per_sweep() {
            let mut row = vec![f(per * 100.0, 2), f(per * dims.len() as f64, 1)];
            for s in &schemes {
                let (ffp, _) = evaluate_scheme(
                    s.as_ref(),
                    dims,
                    per,
                    FaultModel::Random,
                    opts.seed,
                    opts.n_configs(),
                    opts.threads,
                );
                row.push(f(ffp, 4));
            }
            t.push_row(row);
        }
        Ok(vec![t])
    }
}
