//! `audit` — latency attribution + fault forensics (`repro audit`,
//! DESIGN.md §11).
//!
//! Runs four scenario presets traced with the streaming span ledger
//! ([`crate::obs::attrib::SpanLedger`]) teed alongside a buffering
//! sink for the windowed collector:
//!
//! * `degraded_continuity` — the drain/re-admit scenario: the preset
//!   where fault episodes, re-sharding and fault-induced stall are
//!   load-bearing;
//! * `open_steady`, `flash_crowd`, `open_diurnal` — the open-loop
//!   traffic presets, where head-of-line blocking and batch-formation
//!   wait dominate.
//!
//! For every completed request the five attribution components sum
//! **exactly** to its end-to-end cycles — asserted here on every run,
//! property-tested in `rust/tests/audit.rs`. The machine-readable
//! baseline (`BENCH_audit.json`, schema `hyca-audit-bench-v1`) is a
//! pure function of the master seed, byte-identical at any
//! `--workers` value; per-chip utilization is priced from the
//! timeseries collector's busy-lane gauge (the integral the ledger
//! cross-checks), so the audit and `BENCH_traffic.json` can never
//! disagree about occupancy.

use std::sync::Arc;

use super::{Experiment, RunOpts};
use crate::fleet::metrics::FleetReport;
use crate::fleet::{self, FleetConfig};
use crate::inference::Engine;
use crate::obs::attrib::{AuditReport, SpanLedger};
use crate::obs::{timeseries, MemorySink, TeeSink, TimeSeries};
use crate::scenario::{self, Cell, ScenarioSpec};
use crate::util::table::{f, Table};
use anyhow::{ensure, Result};

pub struct AuditExp;

/// The audited presets, in presentation order: the fault-forensics
/// scenario first, then the three open-loop traffic presets.
pub const PRESETS: [&str; 4] =
    ["degraded_continuity", "open_steady", "flash_crowd", "open_diurnal"];

fn audit_spec(name: &str) -> ScenarioSpec {
    scenario::preset(name).expect("audit preset is registered")
}

/// Lower one audited preset into its runnable [`FleetConfig`] (public
/// so the integration tests run exactly what the bench reports).
pub fn audit_config(name: &str, seed: u64, smoke: bool, threads: usize) -> FleetConfig {
    let spec = audit_spec(name);
    scenario::lower_fleet(&spec, &Cell::base(&spec), smoke, seed, threads)
}

/// One preset's results: the fleet report, the closed span ledger and
/// the windowed series.
pub struct PresetAudit {
    pub name: String,
    pub hash: String,
    pub report: FleetReport,
    pub audit: AuditReport,
    pub series: TimeSeries,
}

/// Run one preset traced: the span ledger streams the emissions while
/// a memory sink buffers them for the windowed collector.
pub fn run_preset(
    engine: &Arc<Engine>,
    name: &str,
    opts: &RunOpts,
    smoke: bool,
) -> Result<PresetAudit> {
    let spec = audit_spec(name);
    let hash = spec.spec_hash();
    let cfg = audit_config(name, opts.seed, smoke, opts.threads);
    let mut ledger = SpanLedger::new(&cfg.lane_counts());
    let mut mem = MemorySink::default();
    let report = {
        let mut tee = TeeSink { a: &mut ledger, b: &mut mem };
        fleet::run_traced(engine, &cfg, &mut tee)?
    };
    let audit = ledger.finish(report.total_cycles, &report.correct);
    // the attribution contract, enforced on every run of every preset:
    // components sum exactly to end-to-end cycles
    for sp in &audit.spans {
        ensure!(
            sp.components_sum() == sp.end_to_end(),
            "attribution leak on {name} request {}: components {} != e2e {}",
            sp.id,
            sp.components_sum(),
            sp.end_to_end()
        );
    }
    ensure!(
        audit.spans.len() == report.total_requests,
        "{name}: ledger closed {} spans for {} admitted requests",
        audit.spans.len(),
        report.total_requests
    );
    let series = timeseries::collect(
        &mem.events,
        report.total_cycles,
        timeseries::DEFAULT_WINDOWS,
        report.chips,
        report.active_chips[0].1,
    );
    // the collector's busy-lane integral and the ledger's must agree
    // (same stream, two independent folds)
    for c in &audit.chips {
        let windowed: u64 =
            series.windows.iter().map(|w| w.per_chip_busy_lane_cycles[c.chip]).sum();
        ensure!(
            windowed == c.busy_lane_cycles,
            "{name} chip {}: collector occupancy {windowed} != ledger {}",
            c.chip,
            c.busy_lane_cycles
        );
    }
    Ok(PresetAudit { name: name.to_string(), hash, report, audit, series })
}

fn run_presets(opts: &RunOpts, smoke: bool, only: Option<&str>) -> Result<Vec<PresetAudit>> {
    let engine = Arc::new(Engine::builtin());
    let mut out = Vec::new();
    for name in PRESETS {
        if only.is_some_and(|o| o != name) {
            continue;
        }
        out.push(run_preset(&engine, name, opts, smoke)?);
    }
    ensure!(!out.is_empty(), "unknown audit preset — choose from: {}", PRESETS.join(", "));
    Ok(out)
}

fn attribution_table(results: &[PresetAudit]) -> Table {
    let mut t = Table::new(
        "latency attribution — where every admitted request's \
         end-to-end cycles went (components sum exactly to e2e) \
         [model: builtin, backend: native]",
        &[
            "scenario",
            "requests",
            "e2e_cycles",
            "batch_wait",
            "queue_wait",
            "fault_stall",
            "execution",
            "stalled",
            "resharded",
        ],
    );
    for run in results {
        let (e2e, _adm, batch, queue, fault, exec) = run.audit.totals();
        let stalled = run.audit.spans.iter().filter(|s| s.fault_stall > 0).count();
        let resharded = run.audit.spans.iter().filter(|s| s.reshards > 0).count();
        t.push_row(vec![
            run.name.clone(),
            run.audit.spans.len().to_string(),
            e2e.to_string(),
            batch.to_string(),
            queue.to_string(),
            fault.to_string(),
            exec.to_string(),
            stalled.to_string(),
            resharded.to_string(),
        ]);
    }
    t
}

fn episode_table(results: &[PresetAudit]) -> Table {
    let mut t = Table::new(
        "fault forensics — per-episode cost (cycles in simulated time; \
         an open episode never resolved inside the run)",
        &[
            "scenario",
            "chip",
            "start",
            "end",
            "faults",
            "remaps",
            "remap_lat_mean",
            "stalled",
            "cycles_lost",
            "dip_accuracy",
        ],
    );
    for run in results {
        for e in &run.audit.episodes {
            t.push_row(vec![
                run.name.clone(),
                e.chip.to_string(),
                e.start_cycle.to_string(),
                e.end_cycle.map_or("open".to_string(), |c| c.to_string()),
                e.faults.to_string(),
                e.remaps.to_string(),
                e.mean_remap_latency().map_or("-".to_string(), |m| f(m, 1)),
                e.requests_stalled.to_string(),
                e.cycles_lost.to_string(),
                e.dip_accuracy().map_or("-".to_string(), |a| f(a, 4)),
            ]);
        }
    }
    t
}

fn utilization_table(results: &[PresetAudit]) -> Table {
    let mut t = Table::new(
        "per-chip occupancy — utilization from the timeseries \
         collector's busy-lane gauge; hol = all-lanes-busy \
         (head-of-line-blocking) cycles",
        &["scenario", "chip", "lanes", "served", "utilization", "hol_cycles", "drained_cycles"],
    );
    for run in results {
        for c in &run.audit.chips {
            t.push_row(vec![
                run.name.clone(),
                c.chip.to_string(),
                c.lanes.to_string(),
                c.served.to_string(),
                f(c.utilization(run.audit.horizon), 4),
                c.hol_cycles.to_string(),
                c.drained_cycles.to_string(),
            ]);
        }
    }
    t
}

fn episode_json(run: &PresetAudit) -> String {
    let rows: Vec<String> = run
        .audit
        .episodes
        .iter()
        .map(|e| {
            format!(
                "      {{\"chip\": {}, \"start_cycle\": {}, \"end_cycle\": {}, \
                 \"faults\": {}, \"remaps\": {}, \"mean_remap_latency\": {}, \
                 \"max_remap_latency\": {}, \"requests_stalled\": {}, \
                 \"cycles_lost\": {}, \"dip_requests\": {}, \"dip_accuracy\": {}}}",
                e.chip,
                e.start_cycle,
                e.end_cycle.map_or("null".to_string(), |c| c.to_string()),
                e.faults,
                e.remaps,
                e.mean_remap_latency().map_or("null".to_string(), |m| format!("{m:.6}")),
                e.remap_latency_max,
                e.requests_stalled,
                e.cycles_lost,
                e.dip_requests,
                e.dip_accuracy().map_or("null".to_string(), |a| format!("{a:.6}")),
            )
        })
        .collect();
    if rows.is_empty() {
        "[]".to_string()
    } else {
        format!("[\n{}\n     ]", rows.join(",\n"))
    }
}

fn chips_json(run: &PresetAudit) -> String {
    let rows: Vec<String> = run
        .audit
        .chips
        .iter()
        .map(|c| {
            format!(
                "      {{\"chip\": {}, \"lanes\": {}, \"served\": {}, \
                 \"busy_lane_cycles\": {}, \"utilization\": {:.6}, \
                 \"hol_cycles\": {}, \"drained_cycles\": {}}}",
                c.chip,
                c.lanes,
                c.served,
                c.busy_lane_cycles,
                c.utilization(run.audit.horizon),
                c.hol_cycles,
                c.drained_cycles,
            )
        })
        .collect();
    format!("[\n{}\n     ]", rows.join(",\n"))
}

fn audit_json(seed: u64, smoke: bool, results: &[PresetAudit]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-audit-bench-v1\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"presets\": [\n");
    for (i, run) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        let (e2e, adm, batch, queue, fault, exec) = run.audit.totals();
        let stalled = run.audit.spans.iter().filter(|s| s.fault_stall > 0).count();
        let resharded = run.audit.spans.iter().filter(|s| s.reshards > 0).count();
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"spec_hash\": \"{}\", \"n_chips\": {}, \
             \"requests\": {}, \"horizon_cycles\": {},\n     \
             \"attribution\": {{\"end_to_end_cycles\": {e2e}, \
             \"admission_wait_cycles\": {adm}, \"batch_wait_cycles\": {batch}, \
             \"queue_wait_cycles\": {queue}, \"fault_stall_cycles\": {fault}, \
             \"execution_cycles\": {exec}}},\n     \
             \"stalled_requests\": {stalled}, \"resharded_requests\": {resharded},\n     \
             \"episodes\": {},\n     \
             \"chips\": {}}}{sep}\n",
            run.name,
            run.hash,
            run.report.chips,
            run.audit.spans.len(),
            run.audit.horizon,
            episode_json(run),
            chips_json(run),
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Full run: report tables + the JSON baseline. `only` restricts to a
/// single preset (`repro audit <preset>` — tables only, no baseline).
pub fn run_full(opts: &RunOpts, smoke: bool, only: Option<&str>) -> Result<(Vec<Table>, String)> {
    let results = run_presets(opts, smoke, only)?;
    let json = audit_json(opts.seed, smoke, &results);
    let mut tables = vec![attribution_table(&results), utilization_table(&results)];
    if results.iter().any(|r| !r.audit.episodes.is_empty()) {
        tables.insert(1, episode_table(&results));
    }
    Ok((tables, json))
}

/// The JSON baseline alone (what `BENCH_audit.json` holds and the
/// golden test compares across `--workers` values).
pub fn bench_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let results = run_presets(opts, smoke, None)?;
    Ok(audit_json(opts.seed, smoke, &results))
}

impl Experiment for AuditExp {
    fn id(&self) -> &'static str {
        "audit"
    }

    fn title(&self) -> &'static str {
        "Audit: latency attribution + fault forensics over the trace bus"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast, None)?;
        Ok(tables)
    }
}
