//! Fig. 11 (§V-C): normalised remaining computing power after the
//! column-discard degradation policy, RR/CR/DR/HyCA32 under both fault
//! models — HyCA's left-first repair keeps ~25× more array alive than
//! RR at 6% PER.

use super::{exp_fig10::schemes, Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::montecarlo::FaultModel;
use crate::redundancy::evaluate_scheme;
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig11;

impl Experiment for Fig11 {
    fn id(&self) -> &'static str {
        "fig11"
    }

    fn title(&self) -> &'static str {
        "Normalized remaining computing power, RR/CR/DR/HyCA32, both fault models"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let dims = Dims::PAPER;
        let mut tables = Vec::new();
        for model in FaultModel::both() {
            let schemes = schemes();
            let mut t = Table::new(
                format!("Fig.11 ({}) — normalized computing power", model.label()),
                // RR-pPE = per-PE-spare ablation of the RR degradation
                // semantics (see rr.rs; the paper underspecifies it and
                // the metric is sensitive — EXPERIMENTS.md discusses).
                &["PER(%)", "RR", "RR-pPE", "CR", "DR", "HyCA32", "HyCA32/RR"],
            );
            for per in opts.per_sweep() {
                let mut row = vec![f(per * 100.0, 2)];
                let mut rr_power = f64::NAN;
                let mut hyca_power = f64::NAN;
                for (i, s) in schemes.iter().enumerate() {
                    let (_, power) = evaluate_scheme(
                        s.as_ref(),
                        dims,
                        per,
                        model,
                        opts.seed,
                        opts.n_configs(),
                        opts.threads,
                    );
                    if i == 0 {
                        rr_power = power;
                    }
                    if i == 3 {
                        hyca_power = power;
                    }
                    row.push(f(power, 4));
                    if i == 0 {
                        let (_, p2) = evaluate_scheme(
                            &crate::redundancy::rr::RowRedundancy::per_pe_spare(),
                            dims,
                            per,
                            model,
                            opts.seed,
                            opts.n_configs(),
                            opts.threads,
                        );
                        row.push(f(p2, 4));
                    }
                }
                row.push(f(hyca_power / rr_power.max(1e-9), 2));
                t.push_row(row);
            }
            tables.push(t);
        }
        Ok(tables)
    }
}
