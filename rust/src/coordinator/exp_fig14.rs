//! Fig. 14 (§V-E): redundancy scalability — fully functional
//! probability across computing-array sizes (16×16, 32×32, 64×32,
//! 64×64) for all four schemes under both fault models. Spare budgets
//! follow the paper: RR = rows, CR = cols, DR = diagonal per square
//! sub-array, HyCA = Col.

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::montecarlo::FaultModel;
use crate::redundancy::{
    cr::ColumnRedundancy, dr::DiagonalRedundancy, evaluate_scheme, hyca::HycaScheme,
    rr::RowRedundancy, Scheme,
};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig14;

/// The four array sizes of Fig. 14 (a–d / e–h).
pub fn array_sizes() -> [Dims; 4] {
    [
        Dims::new(16, 16),
        Dims::new(32, 32),
        Dims::new(64, 32),
        Dims::new(64, 64),
    ]
}

impl Experiment for Fig14 {
    fn id(&self) -> &'static str {
        "fig14"
    }

    fn title(&self) -> &'static str {
        "FFP scalability across array sizes, both fault models"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let mut tables = Vec::new();
        for model in FaultModel::both() {
            let mut t = Table::new(
                format!("Fig.14 ({}) — FFP by array size", model.label()),
                &["array", "PER(%)", "RR", "CR", "DR", "HyCA(Col)"],
            );
            for dims in array_sizes() {
                // HyCA sized to Col for a fair comparison (§V-E)
                let schemes: Vec<Box<dyn Scheme>> = vec![
                    Box::new(RowRedundancy::default()),
                    Box::new(ColumnRedundancy::default()),
                    Box::new(DiagonalRedundancy),
                    Box::new(HycaScheme::paper(dims.cols)),
                ];
                for per in opts.per_sweep() {
                    let mut row = vec![dims.to_string(), f(per * 100.0, 2)];
                    for s in &schemes {
                        let (ffp, _) = evaluate_scheme(
                            s.as_ref(),
                            dims,
                            per,
                            model,
                            opts.seed,
                            opts.n_configs(),
                            opts.threads,
                        );
                        row.push(f(ffp, 4));
                    }
                    t.push_row(row);
                }
            }
            tables.push(t);
        }
        Ok(tables)
    }
}
