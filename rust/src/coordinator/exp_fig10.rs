//! Fig. 10 (§V-C): fully functional probability of RR/CR/DR/HyCA32
//! under both fault distribution models. The headline reliability
//! result: HyCA holds FFP ≈ 1 until the 32-fault capacity cliff at
//! PER ≈ 3.13% regardless of distribution; the classical schemes decay
//! much earlier, worse under clustering.

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::montecarlo::FaultModel;
use crate::redundancy::{
    cr::ColumnRedundancy, dr::DiagonalRedundancy, evaluate_scheme, hyca::HycaScheme,
    rr::RowRedundancy, Scheme,
};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig10;

pub(super) fn schemes() -> Vec<Box<dyn Scheme>> {
    vec![
        Box::new(RowRedundancy::default()),
        Box::new(ColumnRedundancy::default()),
        Box::new(DiagonalRedundancy),
        Box::new(HycaScheme::paper(32)),
    ]
}

impl Experiment for Fig10 {
    fn id(&self) -> &'static str {
        "fig10"
    }

    fn title(&self) -> &'static str {
        "Fully functional probability, RR/CR/DR/HyCA32, both fault models"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let dims = Dims::PAPER;
        let mut tables = Vec::new();
        for model in FaultModel::both() {
            let schemes = schemes();
            let mut t = Table::new(
                format!("Fig.10 ({}) — fully functional probability", model.label()),
                &["PER(%)", "RR", "CR", "DR", "HyCA32"],
            );
            for per in opts.per_sweep() {
                let mut row = vec![f(per * 100.0, 2)];
                for s in &schemes {
                    let (ffp, _) = evaluate_scheme(
                        s.as_ref(),
                        dims,
                        per,
                        model,
                        opts.seed,
                        opts.n_configs(),
                        opts.threads,
                    );
                    row.push(f(ffp, 4));
                }
                t.push_row(row);
            }
            tables.push(t);
        }
        Ok(tables)
    }
}
