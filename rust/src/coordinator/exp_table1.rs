//! Table I (§V-F): the proportion of network layers whose execution
//! time covers one full fault-detection scan of the 2-D computing
//! array (`Row·Col + Col` cycles), across array sizes 16² … 128².

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::hyca::detect::{layers_covering_scan, scan_cycles};
use crate::perfmodel::networks;
use crate::util::table::Table;
use anyhow::Result;

pub struct Table1;

pub fn array_sizes() -> [Dims; 4] {
    [
        Dims::new(16, 16),
        Dims::new(32, 32),
        Dims::new(64, 64),
        Dims::new(128, 128),
    ]
}

impl Experiment for Table1 {
    fn id(&self) -> &'static str {
        "table1"
    }

    fn title(&self) -> &'static str {
        "Layers whose execution covers a full fault-detection scan"
    }

    fn run(&self, _opts: &RunOpts) -> Result<Vec<Table>> {
        let mut cols = vec!["network".to_string()];
        for d in array_sizes() {
            cols.push(d.to_string());
        }
        let mut t = Table::new(
            self.title(),
            &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for net in networks::benchmark() {
            let mut row = vec![net.name.to_string()];
            for dims in array_sizes() {
                let per_layer = net.layer_cycles(dims).unwrap();
                let covered = layers_covering_scan(dims, &per_layer);
                row.push(format!("{}/{}", covered, per_layer.len()));
            }
            t.push_row(row);
        }
        // scan-time reference row
        let mut scan_row = vec!["scan_cycles".to_string()];
        for dims in array_sizes() {
            scan_row.push(scan_cycles(dims).to_string());
        }
        t.push_row(scan_row);
        Ok(vec![t])
    }
}
