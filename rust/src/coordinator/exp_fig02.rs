//! Fig. 2 (§III-B motivation): prediction accuracy of a network
//! executed on the faulty DLA across random fault configurations and
//! PER setups — *the functional end-to-end experiment*: fault configs
//! are sampled in rust, converted to per-layer stuck-at masks via the
//! output-stationary mapping, and fed to the AOT-compiled quantized
//! CNN through PJRT. We additionally report the HyCA-repaired accuracy
//! (the paper's Fig. 2 is unprotected; the extra column is the
//! end-to-end proof that DPPU repair restores accuracy).
//!
//! Paper: ResNet18 / ImageNet on a 32×32 array, 50 configs/PER. Here:
//! the int8 CNN of DESIGN.md §2 mapped onto an **8×8** array so the
//! model-size : array-size ratio (≈3 output features per PE minimum)
//! stays comparable to ResNet18 : 32×32 — on the full 32×32 array the
//! tiny CNN would exercise only a sliver of the PEs and no fault rate
//! could reproduce the paper's accuracy cliff. Default 12 configs/PER
//! because each inference pass runs the full model.
//!
//! Runs on [`Engine::auto`]: the compiled artifacts when present, the
//! deterministic builtin model on the native backend otherwise — so the
//! experiment (and its golden test, `rust/tests/golden.rs`) is fully
//! hermetic.

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::ber::ber_from_per;
use crate::faults::montecarlo::FaultModel;
use crate::inference::{Engine, LayerMasks};
use crate::redundancy::hyca::HycaScheme;
use crate::redundancy::{RepairCtx, Scheme};
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig02;

impl Experiment for Fig02 {
    fn id(&self) -> &'static str {
        "fig2"
    }

    fn title(&self) -> &'static str {
        "Prediction accuracy vs PER (backend end-to-end), faulty vs HyCA-repaired"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let engine = if opts.builtin_model {
            Engine::builtin()
        } else {
            Engine::auto()
        };
        let dims = Dims::new(8, 8); // see header: ratio-preserving mapping
        let geometry = engine.geometry();
        let hyca = HycaScheme::paper(8); // DPPU sized to Col, as in the paper
        let configs = if opts.fast { 4 } else { 12.min(opts.n_configs()) };
        let pers = [0.0, 0.001, 0.0025, 0.005, 0.01, 0.02, 0.03, 0.06];
        let clean_acc = engine.accuracy(&LayerMasks::identity(&geometry))?;
        // record which model/backend produced these numbers so builtin
        // results can never be mistaken for the artifact reproduction
        let mut t = Table::new(
            format!(
                "{} [model: {}, backend: {}]",
                self.title(),
                engine.source,
                engine.backend.name()
            ),
            &[
                "PER(%)",
                "configs",
                "faulty_mean",
                "faulty_min",
                "faulty_max",
                "repaired_mean",
                "clean",
            ],
        );
        for per in pers {
            let mut faulty_accs = Vec::new();
            let mut repaired_accs = Vec::new();
            for i in 0..configs {
                let cfg =
                    FaultModel::Random.sample_indexed(opts.seed, i as u64, dims, per);
                let ber = ber_from_per(per);
                let faulty = LayerMasks::from_faults(
                    &geometry,
                    &cfg,
                    &|_, _| false,
                    ber.max(1e-6),
                    opts.seed ^ i as u64,
                );
                faulty_accs.push(engine.accuracy(&faulty)?);
                // HyCA repair: everything the DPPU capacity covers
                let mut rng = Pcg32::split(opts.seed ^ 0xF1C5, i as u64);
                let mut ctx = RepairCtx { per, rng: &mut rng };
                let outcome = hyca.repair(&cfg, &mut ctx);
                let repaired_set: std::collections::HashSet<(usize, usize)> =
                    if outcome.fully_functional {
                        cfg.faulty()
                            .iter()
                            .map(|c| (c.row as usize, c.col as usize))
                            .collect()
                    } else {
                        cfg.faulty()
                            .iter()
                            .take(8)
                            .map(|c| (c.row as usize, c.col as usize))
                            .collect()
                    };
                let repaired = LayerMasks::from_faults(
                    &geometry,
                    &cfg,
                    &|r, c| repaired_set.contains(&(r, c)),
                    ber.max(1e-6),
                    opts.seed ^ i as u64,
                );
                repaired_accs.push(engine.accuracy(&repaired)?);
            }
            let fs = Summary::of(&faulty_accs);
            let rs = Summary::of(&repaired_accs);
            t.push_row(vec![
                f(per * 100.0, 2),
                configs.to_string(),
                f(fs.mean, 4),
                f(fs.min, 4),
                f(fs.max, 4),
                f(rs.mean, 4),
                f(clean_acc, 4),
            ]);
        }
        Ok(vec![t])
    }
}
