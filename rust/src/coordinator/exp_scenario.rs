//! `repro scenario <preset|path.scn>` — run *any* declarative
//! [`ScenarioSpec`] end to end: expand its sweep into cells, lower
//! each cell through `scenario::lower`, execute on the serve or fleet
//! pipeline, and render tables plus a machine-readable
//! `BENCH_scenario_<name>.json` stamped with the spec's canonical
//! hash.
//!
//! Row-format compatibility: serve-driver grids render with
//! `exp_serve`'s row format and fleet grids swept only over
//! `chips`/`router` with `exp_fleet`'s — so the `steady_state` /
//! `fleet_default` presets emit grid sections byte-identical to
//! `BENCH_serve.json` / `BENCH_fleet.json`'s. Grids over other axes
//! (topology, fault intensity, ...) use an extended row carrying the
//! axis labels and the fleet-quality columns (availability,
//! load_imbalance).
//!
//! Single-cell specs with fault injection (e.g. `burst`,
//! `degraded_continuity`) additionally render the timeline /
//! breakdown / summary tables of the matching legacy driver.

use std::sync::Arc;

use super::{exp_fleet, exp_serve};
use crate::fleet::{self, metrics::FleetReport};
use crate::inference::Engine;
use crate::scenario::{self, Cell, Driver, ScenarioSpec, SweepAxis};
use crate::serve::{self, metrics::ServeReport};
use crate::util::table::{f, Table};
use anyhow::Result;

/// The reports of one scenario run, cell by cell.
pub enum ScenarioRun {
    Serve(Vec<(Cell, ServeReport)>),
    Fleet(Vec<(Cell, FleetReport)>),
}

/// Execute every cell of the spec's grid on the builtin engine.
pub fn run_cells(
    spec: &ScenarioSpec,
    seed: u64,
    threads: usize,
    smoke: bool,
) -> Result<ScenarioRun> {
    let engine = Arc::new(Engine::builtin());
    Ok(match spec.driver {
        Driver::Serve => {
            let mut out = Vec::new();
            for cell in spec.cells(smoke) {
                let cfg = scenario::lower_serve(spec, &cell, smoke, seed, threads)?;
                out.push((cell, serve::run(&engine, &cfg)?));
            }
            ScenarioRun::Serve(out)
        }
        Driver::Fleet => {
            let mut out = Vec::new();
            for cell in spec.cells(smoke) {
                let cfg = scenario::lower_fleet(spec, &cell, smoke, seed, threads);
                out.push((cell, fleet::run(&engine, &cfg)?));
            }
            ScenarioRun::Fleet(out)
        }
    })
}

/// May the fleet grid reuse the legacy `chips`/`policy` row format?
fn legacy_fleet_shape(spec: &ScenarioSpec) -> bool {
    spec.sweep
        .iter()
        .all(|a| matches!(a, SweepAxis::Chips(_) | SweepAxis::Router(_)))
}

fn generic_fleet_table(spec: &ScenarioSpec, results: &[(Cell, FleetReport)]) -> Table {
    let axis_keys: Vec<&'static str> = spec.sweep.iter().map(|a| a.key()).collect();
    // `chips`/`policy` identify the cell when they are not already
    // sweep axes of their own
    let add_chips = !axis_keys.contains(&"chips");
    let add_policy = !axis_keys.contains(&"router");
    let mut columns: Vec<&str> = axis_keys.clone();
    if add_chips {
        columns.push("chips");
    }
    if add_policy {
        columns.push("policy");
    }
    columns.extend_from_slice(&[
        "requests",
        "imgs_per_Mcycle",
        "p50_cycles",
        "p99_cycles",
        "accuracy",
        "availability",
        "drains",
        "load_imbalance",
    ]);
    let mut t = Table::new(
        format!("scenario {} — fleet grid in simulated cycles", spec.name),
        &columns,
    );
    for (cell, r) in results {
        let mut row: Vec<String> = axis_keys
            .iter()
            .map(|k| {
                cell.labels
                    .iter()
                    .find(|(lk, _)| lk == k)
                    .map(|(_, v)| v.clone())
                    .unwrap_or_else(|| "-".to_string())
            })
            .collect();
        if add_chips {
            row.push(cell.chips.len().to_string());
        }
        if add_policy {
            row.push(cell.policy.to_string());
        }
        row.extend(vec![
            r.total_requests.to_string(),
            f(r.throughput_imgs_per_mcycle, 2),
            r.p50_cycles().to_string(),
            r.p99_cycles().to_string(),
            f(r.accuracy, 4),
            f(r.availability(), 4),
            r.drains().to_string(),
            f(r.load_imbalance(), 4),
        ]);
        t.push_row(row);
    }
    t
}

/// Extended JSON row for non-legacy fleet grids: axis labels first
/// (numeric axes unquoted), then the metric columns.
fn generic_fleet_json_row(cell: &Cell, r: &FleetReport, sep: &str) -> String {
    let mut fields: Vec<String> = Vec::new();
    for (key, value) in &cell.labels {
        match *key {
            "topology" | "router" => fields.push(format!("\"{key}\": \"{value}\"")),
            _ => fields.push(format!("\"{key}\": {value}")),
        }
    }
    if !cell.labels.iter().any(|(k, _)| *k == "chips") {
        fields.push(format!("\"chips\": {}", cell.chips.len()));
    }
    if !cell.labels.iter().any(|(k, _)| *k == "router") {
        fields.push(format!("\"policy\": \"{}\"", cell.policy));
    }
    fields.push(format!("\"requests\": {}", r.total_requests));
    fields.push(format!(
        "\"throughput_imgs_per_mcycle\": {:.6}",
        r.throughput_imgs_per_mcycle
    ));
    fields.push(format!("\"p50_cycles\": {}", r.p50_cycles()));
    fields.push(format!("\"p99_cycles\": {}", r.p99_cycles()));
    fields.push(format!("\"accuracy\": {:.6}", r.accuracy));
    fields.push(format!("\"availability\": {:.6}", r.availability()));
    fields.push(format!("\"load_imbalance\": {:.6}", r.load_imbalance()));
    format!("    {{{}}}{sep}\n", fields.join(", "))
}

/// Assemble the scenario bench JSON: envelope (schema, scenario name,
/// canonical spec hash, seed, mode) around the grid rows.
fn bench_json(spec: &ScenarioSpec, seed: u64, smoke: bool, run: &ScenarioRun) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-scenario-bench-v1\",\n");
    s.push_str(&format!("  \"scenario\": \"{}\",\n", spec.name));
    s.push_str(&format!("  \"spec_hash\": \"{}\",\n", spec.spec_hash()));
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"grid\": [\n");
    match run {
        ScenarioRun::Serve(results) => {
            for (i, (cell, r)) in results.iter().enumerate() {
                let sep = if i + 1 == results.len() { "" } else { "," };
                s.push_str(&exp_serve::json_row(
                    cell.chips[0].lanes,
                    cell.max_batch,
                    r,
                    sep,
                ));
            }
        }
        ScenarioRun::Fleet(results) => {
            let legacy = legacy_fleet_shape(spec);
            for (i, (cell, r)) in results.iter().enumerate() {
                let sep = if i + 1 == results.len() { "" } else { "," };
                if legacy {
                    s.push_str(&exp_fleet::json_row(cell.chips.len(), cell.policy, r, sep));
                } else {
                    s.push_str(&generic_fleet_json_row(cell, r, sep));
                }
            }
        }
    }
    s.push_str("  ]\n}\n");
    s
}

/// Run a spec end to end: tables + bench JSON.
pub fn run_spec(
    spec: &ScenarioSpec,
    seed: u64,
    threads: usize,
    smoke: bool,
) -> Result<(Vec<Table>, String)> {
    let run = run_cells(spec, seed, threads, smoke)?;
    let json = bench_json(spec, seed, smoke, &run);
    let single_faulty_cell = spec.faults.is_some() && spec.cells(smoke).len() == 1;
    let mut tables = Vec::new();
    match &run {
        ScenarioRun::Serve(results) => {
            let rows: Vec<(usize, usize, ServeReport)> = results
                .iter()
                .map(|(c, r)| (c.chips[0].lanes, c.max_batch, r.clone()))
                .collect();
            tables.push(exp_serve::grid_table(&rows));
            if single_faulty_cell {
                let report = &results[0].1;
                tables.push(exp_serve::scenario_table(report));
                tables.push(exp_serve::scenario_summary(report));
            }
        }
        ScenarioRun::Fleet(results) => {
            if legacy_fleet_shape(spec) {
                let rows: Vec<(usize, fleet::RoutingPolicy, FleetReport)> = results
                    .iter()
                    .map(|(c, r)| (c.chips.len(), c.policy, r.clone()))
                    .collect();
                tables.push(exp_fleet::grid_table(&rows));
            } else {
                tables.push(generic_fleet_table(spec, results));
            }
            if single_faulty_cell {
                let report = &results[0].1;
                tables.push(exp_fleet::scenario_timeline_table(report));
                tables.push(exp_fleet::scenario_chip_table(report));
                tables.push(exp_fleet::scenario_summary(report, report.total_requests));
            }
        }
    }
    Ok((tables, json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::presets;

    #[test]
    fn steady_state_grid_section_matches_the_serve_baseline() {
        let opts = crate::coordinator::RunOpts {
            seed: 0xC0FFEE,
            threads: 2,
            builtin_model: true,
            ..Default::default()
        };
        let serve_json = exp_serve::bench_json(&opts, true).unwrap();
        let spec = presets::preset("steady_state").unwrap();
        let (_tables, scn_json) = run_spec(&spec, 0xC0FFEE, 2, true).unwrap();
        let section = |s: &str| {
            let start = s.find("\"grid\": [").expect("grid section");
            let end = s[start..].find("\n  ]").expect("section end") + start;
            s[start..end].to_string()
        };
        assert_eq!(
            section(&serve_json),
            section(&scn_json),
            "scenario steady_state must replay the serve grid byte-identically"
        );
    }

    #[test]
    fn fleet_default_grid_section_matches_the_fleet_baseline() {
        let opts = crate::coordinator::RunOpts {
            seed: 0xC0FFEE,
            threads: 2,
            builtin_model: true,
            ..Default::default()
        };
        let fleet_json = exp_fleet::bench_json(&opts, true).unwrap();
        let spec = presets::preset("fleet_default").unwrap();
        let (_tables, scn_json) = run_spec(&spec, 0xC0FFEE, 2, true).unwrap();
        let section = |s: &str| {
            let start = s.find("\"grid\": [").expect("grid section");
            let end = s[start..].find("\n  ]").expect("section end") + start;
            s[start..end].to_string()
        };
        assert_eq!(section(&fleet_json), section(&scn_json));
    }

    #[test]
    fn scenario_json_carries_the_spec_hash_and_name() {
        let spec = presets::preset("burst").unwrap();
        let (tables, json) = run_spec(&spec, 3, 1, true).unwrap();
        assert!(json.contains("\"schema\": \"hyca-scenario-bench-v1\""));
        assert!(json.contains("\"scenario\": \"burst\""));
        assert!(json.contains(&format!("\"spec_hash\": \"{}\"", spec.spec_hash())));
        // a single faulty cell renders the timeline + summary tables
        assert_eq!(tables.len(), 3);
        assert!(tables[2].to_markdown().contains("recovered_exactly"));
    }

    #[test]
    fn uneven_faults_uses_the_extended_row_format() {
        let spec = presets::preset("uneven_faults").unwrap();
        let (tables, json) = run_spec(&spec, 0xC0FFEE, 2, true).unwrap();
        assert!(json.contains("\"fault_mean\": 8000"));
        assert!(json.contains("\"load_imbalance\":"));
        assert!(json.contains("\"availability\":"));
        let grid = tables[0].to_markdown();
        assert!(grid.contains("fault_mean") && grid.contains("availability"));
    }
}
