//! Fig. 9 (§V-B): chip area of the DLA under the different redundancy
//! approaches (RR, CR, DR, HyCA24/32/40) — component-level GE model,
//! see `crate::area` for the substitution rationale.

use super::{Experiment, RunOpts};
use crate::area::{dla_area, fig9_lineup, AreaConstants};
use crate::array::Dims;
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig09;

impl Experiment for Fig09 {
    fn id(&self) -> &'static str {
        "fig9"
    }

    fn title(&self) -> &'static str {
        "Chip area under different redundancy approaches (kGE)"
    }

    fn run(&self, _opts: &RunOpts) -> Result<Vec<Table>> {
        let consts = AreaConstants::default();
        let mut t = Table::new(
            self.title(),
            &[
                "design",
                "base_array",
                "buffers",
                "red_PEs",
                "MUX",
                "regfiles",
                "control",
                "overhead",
                "total",
                "overhead_vs_RR",
            ],
        );
        let rr_overhead = dla_area(&consts, Dims::PAPER, crate::area::AreaScheme::Rr)
            .overhead_kge();
        for scheme in fig9_lineup() {
            let a = dla_area(&consts, Dims::PAPER, scheme);
            t.push_row(vec![
                scheme.label(),
                f(a.base_array_kge, 0),
                f(a.buffers_kge, 0),
                f(a.redundant_pes_kge, 1),
                f(a.mux_kge, 1),
                f(a.regfiles_kge, 1),
                f(a.control_kge, 1),
                f(a.overhead_kge(), 1),
                f(a.total_kge(), 0),
                f(a.overhead_kge() / rr_overhead, 3),
            ]);
        }
        Ok(vec![t])
    }
}
