//! Fig. 13 (§V-D): neural-network runtime vs computing-array width
//! (row size fixed at 32) — explains why the performance gap in
//! Fig. 12 is smaller than the computing-power gap in Fig. 11 (runtime
//! is sub-linear in array width, and FC layers use one column only).

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::perfmodel::networks;
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig13;

impl Experiment for Fig13 {
    fn id(&self) -> &'static str {
        "fig13"
    }

    fn title(&self) -> &'static str {
        "NN runtime (Mcycles) vs array width, rows fixed at 32"
    }

    fn run(&self, _opts: &RunOpts) -> Result<Vec<Table>> {
        let widths = [4usize, 8, 12, 16, 20, 24, 28, 32, 40, 48, 56, 64];
        let nets = networks::benchmark();
        let mut cols = vec!["cols".to_string()];
        cols.extend(nets.iter().map(|n| n.name.to_string()));
        let mut t = Table::new(
            self.title(),
            &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for w in widths {
            let mut row = vec![w.to_string()];
            for net in &nets {
                let cy = net.cycles(Dims::new(32, w)).unwrap();
                row.push(f(cy as f64 / 1e6, 2));
            }
            t.push_row(row);
        }
        Ok(vec![t])
    }
}
