//! `fleet` — the multi-chip sharded-serving experiment (`repro
//! fleet`): a scaling grid over cluster size × routing policy, plus
//! the drain/re-admit scenario — a chip crosses the live-fault
//! threshold, is drained out of the serving set, repaired by its scan
//! agent, re-admitted, and the fleet recovers to exactly 1.0 accuracy
//! with zero dropped requests.
//!
//! Always runs on the **builtin** engine (same rationale as
//! `exp_serve`): exact recovery is a bit-exactness contract of the
//! synthetic argmax labels, and the machine-readable baseline
//! (`BENCH_fleet.json`, schema `hyca-fleet-bench-v1`) must never
//! depend on local artifact state.
//!
//! Determinism contract (asserted by `rust/tests/fleet.rs`): the JSON
//! and every table are byte-identical for a given master seed at any
//! `--workers` value — the same cycle-time contract as serve, now
//! cluster-wide.

use std::sync::Arc;

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::fleet::metrics::FleetReport;
use crate::fleet::{self, ChipSpec, FleetConfig, FleetEventKind, RoutingPolicy, NEVER_DRAIN};
use crate::inference::Engine;
use crate::serve::FaultPlan;
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct FleetExp;

/// Full grid: cluster sizes × every routing policy.
pub const GRID_CHIPS: [usize; 4] = [1, 2, 4, 8];
/// Reduced grid for `--smoke` / `--fast` (CI).
pub const SMOKE_CHIPS: [usize; 2] = [1, 4];

fn grid(smoke: bool, chips_override: Option<usize>) -> Vec<(usize, RoutingPolicy)> {
    let sizes: Vec<usize> = match chips_override {
        Some(n) => vec![n],
        None => {
            if smoke {
                SMOKE_CHIPS.to_vec()
            } else {
                GRID_CHIPS.to_vec()
            }
        }
    };
    let mut cells = Vec::new();
    for &n in &sizes {
        for policy in RoutingPolicy::all() {
            cells.push((n, policy));
        }
    }
    cells
}

/// One fault-free grid cell: `n_chips` homogeneous 8×8 chips with two
/// lanes each; clients scale with cluster capacity so every chip stays
/// saturated and the comparison isolates routing + scale. Public so
/// `benches/fleet_scale.rs` measures exactly the workload
/// `BENCH_fleet.json` reports.
pub fn fleet_cell(
    seed: u64,
    n_chips: usize,
    policy: RoutingPolicy,
    smoke: bool,
    threads: usize,
) -> FleetConfig {
    let clients = (n_chips * 2 * 8).max(8);
    FleetConfig {
        seed,
        chips: vec![
            ChipSpec {
                dims: Dims::new(8, 8),
                lanes: 2,
            };
            n_chips
        ],
        policy,
        max_batch: 8,
        max_wait_cycles: 8_000,
        clients,
        think_cycles: 500,
        total_requests: if smoke { 32 * n_chips } else { 96 * n_chips },
        queue_cap: clients,
        executor_threads: threads,
        windows: 4,
        faults: None,
        drain_threshold: NEVER_DRAIN,
    }
}

/// The drain/re-admit scenario: three chips under independent
/// fault-arrival streams with a live-fault drain threshold of 2, so a
/// chip accumulating two unremapped faults leaves the serving set,
/// gets repaired by its scan agent, and rejoins — while the
/// health-aware router re-shards its traffic and the fleet keeps
/// serving every request.
pub fn scenario_config(seed: u64, smoke: bool, threads: usize) -> FleetConfig {
    FleetConfig {
        seed,
        chips: vec![
            ChipSpec {
                dims: Dims::new(8, 8),
                lanes: 2,
            };
            3
        ],
        policy: RoutingPolicy::HealthWeighted,
        max_batch: 8,
        max_wait_cycles: 8_000,
        clients: 24,
        think_cycles: 500,
        total_requests: if smoke { 192 } else { 432 },
        queue_cap: 24,
        executor_threads: threads,
        windows: 10,
        faults: Some(FaultPlan {
            // arrivals concentrate early (short horizon) so the run's
            // tail demonstrates re-admission and exact recovery
            mean_interarrival_cycles: if smoke { 6_000.0 } else { 20_000.0 },
            horizon_cycles: if smoke { 40_000 } else { 160_000 },
            scan_period_cycles: if smoke { 4_000 } else { 16_000 },
            group_width: 8,
            fpt_capacity: 8,
            max_arrivals: 6,
        }),
        drain_threshold: 2,
    }
}

fn run_grid(
    engine: &Arc<Engine>,
    opts: &RunOpts,
    smoke: bool,
    chips_override: Option<usize>,
) -> Result<Vec<(usize, RoutingPolicy, FleetReport)>> {
    let mut out = Vec::new();
    for (n_chips, policy) in grid(smoke, chips_override) {
        let cfg = fleet_cell(opts.seed, n_chips, policy, smoke, opts.threads);
        let report = fleet::run(engine, &cfg)?;
        out.push((n_chips, policy, report));
    }
    Ok(out)
}

fn grid_table(results: &[(usize, RoutingPolicy, FleetReport)]) -> Table {
    let mut t = Table::new(
        "fleet grid — cluster size × routing policy, metrics in \
         simulated cycles [model: builtin, backend: native]",
        &[
            "chips",
            "policy",
            "requests",
            "batches",
            "mean_batch",
            "imgs_per_Mcycle",
            "p50_cycles",
            "p99_cycles",
            "accuracy",
        ],
    );
    for (n_chips, policy, r) in results {
        t.push_row(vec![
            n_chips.to_string(),
            policy.to_string(),
            r.total_requests.to_string(),
            r.batches.to_string(),
            f(r.mean_batch_size, 2),
            f(r.throughput_imgs_per_mcycle, 2),
            r.p50_cycles().to_string(),
            r.p99_cycles().to_string(),
            f(r.accuracy, 4),
        ]);
    }
    t
}

/// Render the machine-readable perf baseline. Simulated cycles only —
/// no wall-clock fields, reproducible byte-for-byte from the seed at
/// any `--workers` value.
fn grid_json(
    seed: u64,
    smoke: bool,
    results: &[(usize, RoutingPolicy, FleetReport)],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-fleet-bench-v1\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"grid\": [\n");
    for (i, (n_chips, policy, r)) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"chips\": {n_chips}, \"policy\": \"{policy}\", \
             \"requests\": {}, \"batches\": {}, \
             \"throughput_imgs_per_mcycle\": {:.6}, \
             \"p50_cycles\": {}, \"p99_cycles\": {}, \
             \"accuracy\": {:.6}}}{sep}\n",
            r.total_requests,
            r.batches,
            r.throughput_imgs_per_mcycle,
            r.p50_cycles(),
            r.p99_cycles(),
            r.accuracy,
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn scenario_timeline_table(report: &FleetReport) -> Table {
    let mut t = Table::new(
        "fleet under mid-run faults — goodput/accuracy/availability \
         timeline (windows in simulated cycles)",
        &["window", "start", "end", "goodput", "accuracy", "availability", "events"],
    );
    let last_index = report.windows.len().saturating_sub(1);
    for w in &report.windows {
        // scans and lifecycle transitions keep running after traffic
        // ends; fold late events into the last row rather than dropping
        // them (same convention as the serve table)
        let evs: Vec<String> = report
            .events
            .iter()
            .filter(|e| {
                e.cycle >= w.start_cycle && (e.cycle < w.end_cycle || w.index == last_index)
            })
            .map(|e| match e.kind {
                FleetEventKind::FaultArrival(c) => {
                    format!("chip{}:fault@({},{})", e.chip, c.row, c.col)
                }
                FleetEventKind::ScanDetection(c) => {
                    format!("chip{}:remap@({},{})", e.chip, c.row, c.col)
                }
                FleetEventKind::Drained => format!("chip{}:DRAIN", e.chip),
                FleetEventKind::Readmitted => format!("chip{}:READMIT", e.chip),
            })
            .collect();
        t.push_row(vec![
            w.index.to_string(),
            w.start_cycle.to_string(),
            w.end_cycle.to_string(),
            w.requests.to_string(),
            match w.accuracy() {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            f(w.availability, 4),
            if evs.is_empty() { "-".to_string() } else { evs.join(" ") },
        ]);
    }
    t
}

fn scenario_chip_table(report: &FleetReport) -> Table {
    let mut t = Table::new(
        "fleet scenario — per-chip breakdown",
        &[
            "chip",
            "array",
            "lanes",
            "requests",
            "accuracy",
            "p99_cycles",
            "drains",
            "drained_kcycles",
            "unrepaired",
        ],
    );
    for c in &report.per_chip {
        t.push_row(vec![
            c.chip.to_string(),
            c.dims.to_string(),
            c.lanes.to_string(),
            c.requests.to_string(),
            match c.accuracy() {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            c.latency_cycles.quantile(0.99).to_string(),
            c.drains.to_string(),
            (c.drained_cycles / 1000).to_string(),
            c.unrepaired.to_string(),
        ]);
    }
    t
}

fn scenario_summary(report: &FleetReport, budget: usize) -> Table {
    let arrivals = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, FleetEventKind::FaultArrival(_)))
        .count();
    let detections = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, FleetEventKind::ScanDetection(_)))
        .count();
    let readmits = report
        .events
        .iter()
        .filter(|e| e.kind == FleetEventKind::Readmitted)
        .count();
    let recovered = report.unrepaired == 0 && report.final_window_accuracy() == Some(1.0);
    let mut t = Table::new("fleet scenario summary", &["metric", "value"]);
    t.push_row(vec!["chips".into(), report.chips.to_string()]);
    t.push_row(vec!["policy".into(), report.policy.to_string()]);
    t.push_row(vec!["fault_arrivals".into(), arrivals.to_string()]);
    t.push_row(vec!["scan_detections".into(), detections.to_string()]);
    t.push_row(vec!["drain_episodes".into(), report.drains().to_string()]);
    t.push_row(vec!["readmissions".into(), readmits.to_string()]);
    t.push_row(vec!["unrepaired".into(), report.unrepaired.to_string()]);
    t.push_row(vec![
        "requests_served".into(),
        format!("{} / {}", report.total_requests, budget),
    ]);
    t.push_row(vec!["availability".into(), f(report.availability(), 4)]);
    t.push_row(vec!["overall_accuracy".into(), f(report.accuracy, 4)]);
    t.push_row(vec![
        "final_window_accuracy".into(),
        match report.final_window_accuracy() {
            Some(a) => f(a, 4),
            None => "-".to_string(),
        },
    ]);
    t.push_row(vec!["recovered_exactly".into(), recovered.to_string()]);
    t
}

/// Grid + scenario; returns the report tables and the JSON baseline.
/// `chips_override` restricts the grid to one cluster size (`--chips`).
pub fn run_full(
    opts: &RunOpts,
    smoke: bool,
    chips_override: Option<usize>,
) -> Result<(Vec<Table>, String)> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke, chips_override)?;
    let json = grid_json(opts.seed, smoke, &grid_results);
    let scenario_cfg = scenario_config(opts.seed, smoke, opts.threads);
    let scenario = fleet::run(&engine, &scenario_cfg)?;
    let tables = vec![
        grid_table(&grid_results),
        scenario_timeline_table(&scenario),
        scenario_chip_table(&scenario),
        scenario_summary(&scenario, scenario_cfg.total_requests),
    ];
    Ok((tables, json))
}

/// The JSON baseline alone (what `BENCH_fleet.json` holds and the
/// golden test compares across `--workers` values).
pub fn bench_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke, None)?;
    Ok(grid_json(opts.seed, smoke, &grid_results))
}

/// The drain scenario alone (used by `rust/tests/fleet.rs`).
pub fn scenario_report(opts: &RunOpts, smoke: bool) -> Result<FleetReport> {
    let engine = Arc::new(Engine::builtin());
    fleet::run(&engine, &scenario_config(opts.seed, smoke, opts.threads))
}

impl Experiment for FleetExp {
    fn id(&self) -> &'static str {
        "fleet"
    }

    fn title(&self) -> &'static str {
        "Fleet: multi-chip sharded serving — routing-policy grid + drain/re-admit under faults"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast, None)?;
        Ok(tables)
    }
}
