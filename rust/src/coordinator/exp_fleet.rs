//! `fleet` — the multi-chip sharded-serving experiment (`repro
//! fleet`): a scaling grid over cluster size × routing policy, a
//! mixed-fleet grid over heterogeneous array sizes with a
//! routing-quality metric, plus the drain/re-admit scenario — a chip
//! crosses the live-fault threshold, is drained out of the serving
//! set, repaired by its scan agent, re-admitted, and the fleet
//! recovers to exactly 1.0 accuracy with zero dropped requests.
//!
//! This driver is *thin*: it owns no experiment configuration. The
//! scaling grid is the `fleet_default` scenario preset, the
//! heterogeneous grid is `mixed_fleet`, and the drain scenario is
//! `degraded_continuity` (`crate::scenario::presets`); everything
//! lowers into [`FleetConfig`]s through `scenario::lower`, so `repro
//! fleet` and `repro scenario fleet_default` are the same computation
//! — the compatibility bar `rust/tests/scenario.rs` pins byte-exactly
//! (the `grid` section of `BENCH_fleet.json` is unchanged from schema
//! v1; v2 adds the `mixed_fleet` section).
//!
//! Always runs on the **builtin** engine (same rationale as
//! `exp_serve`): exact recovery is a bit-exactness contract of the
//! synthetic argmax labels, and the machine-readable baseline
//! (`BENCH_fleet.json`, schema `hyca-fleet-bench-v2`) must never
//! depend on local artifact state.
//!
//! Determinism contract (asserted by `rust/tests/fleet.rs`): the JSON
//! and every table are byte-identical for a given master seed at any
//! `--workers` value — the same cycle-time contract as serve, now
//! cluster-wide.

use std::sync::Arc;

use super::{Experiment, RunOpts};
use crate::fleet::metrics::FleetReport;
use crate::fleet::{self, FleetConfig, FleetEventKind, RoutingPolicy};
use crate::inference::Engine;
use crate::scenario::{self, topology_label, Cell, ScenarioSpec};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct FleetExp;

fn fleet_default() -> ScenarioSpec {
    scenario::preset("fleet_default").expect("fleet_default preset is registered")
}

fn mixed_fleet() -> ScenarioSpec {
    scenario::preset("mixed_fleet").expect("mixed_fleet preset is registered")
}

fn degraded_continuity() -> ScenarioSpec {
    scenario::preset("degraded_continuity").expect("degraded_continuity preset is registered")
}

/// One fault-free grid cell, lowered from the `fleet_default` preset:
/// `n_chips` homogeneous 8×8 chips with two lanes each; clients scale
/// with cluster capacity so every chip stays saturated and the
/// comparison isolates routing + scale. Public so
/// `benches/fleet_scale.rs` measures exactly the workload
/// `BENCH_fleet.json` reports.
pub fn fleet_cell(
    seed: u64,
    n_chips: usize,
    policy: RoutingPolicy,
    smoke: bool,
    threads: usize,
) -> FleetConfig {
    let spec = fleet_default();
    let cell = Cell::base(&spec).with_chips(n_chips).with_policy(policy);
    scenario::lower_fleet(&spec, &cell, smoke, seed, threads)
}

/// The drain/re-admit scenario, lowered from the `degraded_continuity`
/// preset: three chips under independent fault-arrival streams with a
/// live-fault drain threshold of 2, so a chip accumulating two
/// unremapped faults leaves the serving set, gets repaired by its scan
/// agent, and rejoins — while the health-aware router re-shards its
/// traffic and the fleet keeps serving every request.
pub fn scenario_config(seed: u64, smoke: bool, threads: usize) -> FleetConfig {
    let spec = degraded_continuity();
    scenario::lower_fleet(&spec, &Cell::base(&spec), smoke, seed, threads)
}

fn run_grid(
    engine: &Arc<Engine>,
    opts: &RunOpts,
    smoke: bool,
    chips_override: Option<usize>,
) -> Result<Vec<(usize, RoutingPolicy, FleetReport)>> {
    let spec = fleet_default();
    let cells: Vec<Cell> = match chips_override {
        // --chips restricts the grid to one cluster size (policies
        // still sweep)
        Some(n) => RoutingPolicy::all()
            .into_iter()
            .map(|p| Cell::base(&spec).with_chips(n).with_policy(p))
            .collect(),
        None => spec.cells(smoke),
    };
    let mut out = Vec::new();
    for cell in cells {
        let n_chips = cell.chips.len();
        let cfg = scenario::lower_fleet(&spec, &cell, smoke, opts.seed, opts.threads);
        let report = fleet::run(engine, &cfg)?;
        out.push((n_chips, cfg.policy, report));
    }
    Ok(out)
}

/// The heterogeneous-dims grid (`mixed_fleet` preset): topology
/// variants × routing policy, each labeled with its compact topology
/// string.
fn run_mixed(
    engine: &Arc<Engine>,
    opts: &RunOpts,
    smoke: bool,
) -> Result<Vec<(String, RoutingPolicy, FleetReport)>> {
    let spec = mixed_fleet();
    let mut out = Vec::new();
    for cell in spec.cells(smoke) {
        let label = topology_label(&cell.chips);
        let cfg = scenario::lower_fleet(&spec, &cell, smoke, opts.seed, opts.threads);
        let report = fleet::run(engine, &cfg)?;
        out.push((label, cfg.policy, report));
    }
    Ok(out)
}

pub(crate) fn grid_table(results: &[(usize, RoutingPolicy, FleetReport)]) -> Table {
    let mut t = Table::new(
        "fleet grid — cluster size × routing policy, metrics in \
         simulated cycles [model: builtin, backend: native]",
        &[
            "chips",
            "policy",
            "requests",
            "batches",
            "mean_batch",
            "imgs_per_Mcycle",
            "p50_cycles",
            "p99_cycles",
            "accuracy",
        ],
    );
    for (n_chips, policy, r) in results {
        t.push_row(vec![
            n_chips.to_string(),
            policy.to_string(),
            r.total_requests.to_string(),
            r.batches.to_string(),
            f(r.mean_batch_size, 2),
            f(r.throughput_imgs_per_mcycle, 2),
            r.p50_cycles().to_string(),
            r.p99_cycles().to_string(),
            f(r.accuracy, 4),
        ]);
    }
    t
}

fn mixed_table(results: &[(String, RoutingPolicy, FleetReport)]) -> Table {
    let mut t = Table::new(
        "mixed fleet — heterogeneous array sizes × routing policy; \
         load_imbalance = TV distance from the weight-optimal split \
         (0 = optimal)",
        &[
            "topology",
            "policy",
            "requests",
            "imgs_per_Mcycle",
            "p50_cycles",
            "p99_cycles",
            "accuracy",
            "load_imbalance",
        ],
    );
    for (label, policy, r) in results {
        t.push_row(vec![
            label.clone(),
            policy.to_string(),
            r.total_requests.to_string(),
            f(r.throughput_imgs_per_mcycle, 2),
            r.p50_cycles().to_string(),
            r.p99_cycles().to_string(),
            f(r.accuracy, 4),
            f(r.load_imbalance(), 4),
        ]);
    }
    t
}

/// One machine-readable grid row — the byte-stable fleet bench row
/// format shared by `BENCH_fleet.json` and scenario bench files
/// (unchanged from schema v1).
pub(crate) fn json_row(
    n_chips: usize,
    policy: RoutingPolicy,
    r: &FleetReport,
    sep: &str,
) -> String {
    format!(
        "    {{\"chips\": {n_chips}, \"policy\": \"{policy}\", \
         \"requests\": {}, \"batches\": {}, \
         \"throughput_imgs_per_mcycle\": {:.6}, \
         \"p50_cycles\": {}, \"p99_cycles\": {}, \
         \"accuracy\": {:.6}}}{sep}\n",
        r.total_requests,
        r.batches,
        r.throughput_imgs_per_mcycle,
        r.p50_cycles(),
        r.p99_cycles(),
        r.accuracy,
    )
}

/// One mixed-fleet row: topology label + the routing-quality column.
fn mixed_json_row(label: &str, policy: RoutingPolicy, r: &FleetReport, sep: &str) -> String {
    format!(
        "    {{\"topology\": \"{label}\", \"policy\": \"{policy}\", \
         \"requests\": {}, \"throughput_imgs_per_mcycle\": {:.6}, \
         \"p50_cycles\": {}, \"p99_cycles\": {}, \
         \"accuracy\": {:.6}, \"load_imbalance\": {:.6}}}{sep}\n",
        r.total_requests,
        r.throughput_imgs_per_mcycle,
        r.p50_cycles(),
        r.p99_cycles(),
        r.accuracy,
        r.load_imbalance(),
    )
}

/// Render the machine-readable perf baseline. Simulated cycles only —
/// no wall-clock fields, reproducible byte-for-byte from the seed at
/// any `--workers` value. The `grid` section is byte-identical to
/// schema v1; `mixed_fleet` (when present) is the v2 addition.
fn grid_json(
    seed: u64,
    smoke: bool,
    results: &[(usize, RoutingPolicy, FleetReport)],
    mixed: Option<&[(String, RoutingPolicy, FleetReport)]>,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-fleet-bench-v2\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"grid\": [\n");
    for (i, (n_chips, policy, r)) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&json_row(*n_chips, *policy, r, sep));
    }
    match mixed {
        None => s.push_str("  ]\n}\n"),
        Some(rows) => {
            s.push_str("  ],\n");
            s.push_str("  \"mixed_fleet\": [\n");
            for (i, (label, policy, r)) in rows.iter().enumerate() {
                let sep = if i + 1 == rows.len() { "" } else { "," };
                s.push_str(&mixed_json_row(label, *policy, r, sep));
            }
            s.push_str("  ]\n}\n");
        }
    }
    s
}

pub(crate) fn scenario_timeline_table(report: &FleetReport) -> Table {
    let mut t = Table::new(
        "fleet under mid-run faults — goodput/accuracy/availability \
         timeline (windows in simulated cycles)",
        &["window", "start", "end", "goodput", "accuracy", "availability", "events"],
    );
    let last_index = report.windows.len().saturating_sub(1);
    for w in &report.windows {
        // scans and lifecycle transitions keep running after traffic
        // ends; fold late events into the last row rather than dropping
        // them (same convention as the serve table)
        let evs: Vec<String> = report
            .events
            .iter()
            .filter(|e| {
                e.cycle >= w.start_cycle && (e.cycle < w.end_cycle || w.index == last_index)
            })
            .map(|e| match e.kind {
                FleetEventKind::FaultArrival(c) => {
                    format!("chip{}:fault@({},{})", e.chip, c.row, c.col)
                }
                FleetEventKind::ScanDetection(c) => {
                    format!("chip{}:remap@({},{})", e.chip, c.row, c.col)
                }
                FleetEventKind::Drained => format!("chip{}:DRAIN", e.chip),
                FleetEventKind::Readmitted => format!("chip{}:READMIT", e.chip),
                FleetEventKind::ScaledUp => format!("chip{}:SCALE_UP", e.chip),
                FleetEventKind::ScaledDown => format!("chip{}:SCALE_DOWN", e.chip),
            })
            .collect();
        t.push_row(vec![
            w.index.to_string(),
            w.start_cycle.to_string(),
            w.end_cycle.to_string(),
            w.requests.to_string(),
            match w.accuracy() {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            f(w.availability, 4),
            if evs.is_empty() { "-".to_string() } else { evs.join(" ") },
        ]);
    }
    t
}

pub(crate) fn scenario_chip_table(report: &FleetReport) -> Table {
    let mut t = Table::new(
        "fleet scenario — per-chip breakdown (executor_steals is \
         wall-clock observability: nondeterministic, never part of the \
         byte-compared bench JSON)",
        &[
            "chip",
            "array",
            "lanes",
            "requests",
            "accuracy",
            "p99_cycles",
            "drains",
            "drained_kcycles",
            "unrepaired",
            "executor_steals",
        ],
    );
    for c in &report.per_chip {
        t.push_row(vec![
            c.chip.to_string(),
            c.dims.to_string(),
            c.lanes.to_string(),
            c.requests.to_string(),
            match c.accuracy() {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            c.latency_cycles.quantile(0.99).to_string(),
            c.drains.to_string(),
            (c.drained_cycles / 1000).to_string(),
            c.unrepaired.to_string(),
            c.executor_steals.to_string(),
        ]);
    }
    t
}

pub(crate) fn scenario_summary(report: &FleetReport, budget: usize) -> Table {
    let arrivals = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, FleetEventKind::FaultArrival(_)))
        .count();
    let detections = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, FleetEventKind::ScanDetection(_)))
        .count();
    let readmits = report
        .events
        .iter()
        .filter(|e| e.kind == FleetEventKind::Readmitted)
        .count();
    let recovered = report.unrepaired == 0 && report.final_window_accuracy() == Some(1.0);
    let mut t = Table::new("fleet scenario summary", &["metric", "value"]);
    t.push_row(vec!["chips".into(), report.chips.to_string()]);
    t.push_row(vec!["policy".into(), report.policy.to_string()]);
    t.push_row(vec!["fault_arrivals".into(), arrivals.to_string()]);
    t.push_row(vec!["scan_detections".into(), detections.to_string()]);
    t.push_row(vec!["drain_episodes".into(), report.drains().to_string()]);
    t.push_row(vec!["readmissions".into(), readmits.to_string()]);
    t.push_row(vec!["unrepaired".into(), report.unrepaired.to_string()]);
    t.push_row(vec![
        "requests_served".into(),
        format!("{} / {}", report.total_requests, budget),
    ]);
    t.push_row(vec!["availability".into(), f(report.availability(), 4)]);
    t.push_row(vec!["overall_accuracy".into(), f(report.accuracy, 4)]);
    t.push_row(vec![
        "final_window_accuracy".into(),
        match report.final_window_accuracy() {
            Some(a) => f(a, 4),
            None => "-".to_string(),
        },
    ]);
    t.push_row(vec!["recovered_exactly".into(), recovered.to_string()]);
    t
}

/// Scaling grid + mixed-fleet grid + scenario; returns the report
/// tables and the JSON baseline. `chips_override` restricts the
/// scaling grid to one cluster size (`--chips`) and skips the
/// mixed-fleet section (a restricted run is not the baseline).
pub fn run_full(
    opts: &RunOpts,
    smoke: bool,
    chips_override: Option<usize>,
) -> Result<(Vec<Table>, String)> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke, chips_override)?;
    let mixed_results = match chips_override {
        None => Some(run_mixed(&engine, opts, smoke)?),
        Some(_) => None,
    };
    let json = grid_json(opts.seed, smoke, &grid_results, mixed_results.as_deref());
    let scenario_cfg = scenario_config(opts.seed, smoke, opts.threads);
    let scenario = fleet::run(&engine, &scenario_cfg)?;
    let mut tables = vec![grid_table(&grid_results)];
    if let Some(mixed) = &mixed_results {
        tables.push(mixed_table(mixed));
    }
    tables.push(scenario_timeline_table(&scenario));
    tables.push(scenario_chip_table(&scenario));
    tables.push(scenario_summary(&scenario, scenario_cfg.total_requests));
    Ok((tables, json))
}

/// The JSON baseline alone (what `BENCH_fleet.json` holds and the
/// golden test compares across `--workers` values).
pub fn bench_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke, None)?;
    let mixed_results = run_mixed(&engine, opts, smoke)?;
    Ok(grid_json(opts.seed, smoke, &grid_results, Some(&mixed_results)))
}

/// The drain scenario alone (used by `rust/tests/fleet.rs`).
pub fn scenario_report(opts: &RunOpts, smoke: bool) -> Result<FleetReport> {
    let engine = Arc::new(Engine::builtin());
    fleet::run(&engine, &scenario_config(opts.seed, smoke, opts.threads))
}

/// Chrome-trace export of the `degraded_continuity` scenario — the
/// `--trace` target of `repro fleet` (per-chip batch spans, drain/
/// re-admit lifecycle spans, fault/scan/remap instants and re-shard
/// markers, in simulated cycles; loadable at ui.perfetto.dev).
pub fn trace_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let cfg = scenario_config(opts.seed, smoke, opts.threads);
    let mut sink = crate::obs::MemorySink::default();
    let _report = fleet::run_traced(&engine, &cfg, &mut sink)?;
    Ok(crate::obs::trace_export::chrome_trace_json(
        &sink.events,
        "fleet/degraded_continuity",
    ))
}

impl Experiment for FleetExp {
    fn id(&self) -> &'static str {
        "fleet"
    }

    fn title(&self) -> &'static str {
        "Fleet: multi-chip sharded serving — routing grids (incl. mixed arrays) + drain/re-admit"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast, None)?;
        Ok(tables)
    }
}
