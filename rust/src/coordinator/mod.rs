//! Experiment coordinator: one [`Experiment`] per figure/table of the
//! paper's evaluation (§V), a threaded Monte-Carlo driver, and report
//! writers.
//!
//! Every experiment is pure and deterministic given [`RunOpts`] (seed,
//! config count); the CLI (`repro exp <id>`) prints markdown tables and
//! persists CSV under `results/`. EXPERIMENTS.md records a full run.

pub mod exp_fig02;
pub mod exp_fig03;
pub mod exp_fig09;
pub mod exp_fig10;
pub mod exp_fig11;
pub mod exp_fig12;
pub mod exp_fig13;
pub mod exp_fig14;
pub mod exp_fig15;
pub mod exp_audit;
pub mod exp_fleet;
pub mod exp_perf;
pub mod exp_replay;
pub mod exp_scenario;
pub mod exp_serve;
pub mod exp_table1;
pub mod exp_traffic;
pub mod report;

use crate::util::table::Table;
use anyhow::Result;

/// Options shared by all experiments.
#[derive(Debug, Clone)]
pub struct RunOpts {
    /// Monte-Carlo configurations per (PER, scheme, model) point
    /// (paper: 10 000).
    pub configs: usize,
    /// Master seed; every sampled quantity derives from it.
    pub seed: u64,
    /// Worker threads for the Monte-Carlo fan-out.
    pub threads: usize,
    /// Output directory for CSV reports.
    pub out_dir: std::path::PathBuf,
    /// Reduced sweep for quick iterations (`--fast`).
    pub fast: bool,
    /// Force the builtin synthetic model for functional experiments
    /// (`--builtin`): skip the artifact probe so results never depend
    /// on local artifact state. The golden and integration tests set
    /// this for hermetic byte-exact runs.
    pub builtin_model: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            configs: 10_000,
            seed: 0xC0FFEE,
            threads: crate::faults::montecarlo::default_threads(),
            out_dir: "results".into(),
            fast: false,
            builtin_model: false,
        }
    }
}

impl RunOpts {
    /// The PER sweep (fractions), reduced under `--fast`.
    pub fn per_sweep(&self) -> Vec<f64> {
        let full = crate::faults::ber::paper_per_sweep();
        if self.fast {
            full.into_iter().step_by(3).collect()
        } else {
            full
        }
    }

    /// Config count, reduced under `--fast`.
    pub fn n_configs(&self) -> usize {
        if self.fast {
            self.configs.min(500)
        } else {
            self.configs
        }
    }
}

/// One reproducible paper artefact (figure or table).
pub trait Experiment: Sync {
    /// Stable identifier: "fig10", "table1", …
    fn id(&self) -> &'static str;
    /// Paper caption, abbreviated.
    fn title(&self) -> &'static str;
    /// Produce the result tables.
    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>>;
}

/// All experiments in paper order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(exp_fig02::Fig02),
        Box::new(exp_fig03::Fig03),
        Box::new(exp_fig09::Fig09),
        Box::new(exp_fig10::Fig10),
        Box::new(exp_fig11::Fig11),
        Box::new(exp_fig12::Fig12),
        Box::new(exp_fig13::Fig13),
        Box::new(exp_fig14::Fig14),
        Box::new(exp_fig15::Fig15),
        Box::new(exp_table1::Table1),
        Box::new(exp_serve::ServeExp),
        Box::new(exp_fleet::FleetExp),
        Box::new(exp_traffic::TrafficExp),
        Box::new(exp_perf::PerfExp),
        Box::new(exp_audit::AuditExp),
        Box::new(exp_replay::ReplayExp),
    ]
}

/// Look an experiment up by id.
pub fn find(id: &str) -> Option<Box<dyn Experiment>> {
    registry().into_iter().find(|e| e.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_complete() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id()).collect();
        let set: std::collections::HashSet<_> = ids.iter().collect();
        assert_eq!(ids.len(), set.len());
        for want in [
            "fig2", "fig3", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
            "table1", "serve", "fleet", "traffic", "perf", "audit", "replay",
        ] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn find_works() {
        assert!(find("fig10").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn fast_opts_shrink_work() {
        let slow = RunOpts::default();
        let fast = RunOpts { fast: true, ..RunOpts::default() };
        assert!(fast.n_configs() < slow.n_configs());
        assert!(fast.per_sweep().len() < slow.per_sweep().len());
    }
}
