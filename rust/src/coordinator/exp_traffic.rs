//! `traffic` — the open-loop traffic experiment (`repro traffic`):
//! rate-driven arrivals against the fleet with SLO-aware admission
//! control and queue-pressure chip autoscaling (DESIGN.md §9).
//!
//! Three scenario presets cover the control surface:
//!
//! * `open_steady` — one chip far below saturation: the degeneracy
//!   anchor (zero shed, accuracy 1.0, closed-loop steady-state
//!   behaviour recovered from open mode);
//! * `flash_crowd` — a 15× arrival spike over a 4-chip fleet: the
//!   admission controller sheds to protect the SLO while the
//!   autoscaler grows 2→4 active chips and shrinks back;
//! * `open_diurnal` — a sinusoidal day/night rate the autoscaler
//!   tracks between 2 and 4 active chips.
//!
//! Like the other serving drivers this one is thin — every preset
//! lowers through `scenario::lower_fleet`, runs on the **builtin**
//! engine, and the machine-readable baseline (`BENCH_traffic.json`,
//! schema `hyca-traffic-bench-v3`) is a pure function of the master
//! seed: byte-identical at any `--workers` value (pinned by
//! `rust/tests/traffic.rs`). Since PR 7 every preset runs traced
//! (`fleet::run_traced` + [`crate::obs`]): the `scenarios` rows keep
//! their v1 bytes while a `timeseries` section samples the windowed
//! collector — so flash-crowd ramps are visible *between* the
//! autoscale decisions the legacy `active_chips` trajectory records —
//! and `--trace <path>` exports the flash_crowd run as a
//! Perfetto-loadable Chrome trace. Schema v3 adds the per-chip
//! `per_chip_busy_lane_cycles` occupancy series (lane·cycles per
//! window) to the `timeseries` section — the collector gauge the
//! `repro audit` utilization numbers are priced from — leaving the
//! byte-frozen v1 `scenarios` rows untouched.

use std::sync::Arc;

use super::{Experiment, RunOpts};
use crate::fleet::metrics::FleetReport;
use crate::fleet::{self, FleetConfig};
use crate::inference::Engine;
use crate::obs::{timeseries, trace_export, MemorySink, TimeSeries};
use crate::scenario::{self, Cell, ScenarioSpec};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct TrafficExp;

/// The traffic presets, in presentation order.
pub const PRESETS: [&str; 3] = ["open_steady", "flash_crowd", "open_diurnal"];

fn traffic_spec(name: &str) -> ScenarioSpec {
    let spec = scenario::preset(name).expect("traffic preset is registered");
    assert!(spec.workload.mode.is_open(), "{name} must be open-loop");
    spec
}

/// Lower one traffic preset into its runnable [`FleetConfig`] (public
/// so the integration tests run exactly what the bench reports).
pub fn traffic_config(name: &str, seed: u64, smoke: bool, threads: usize) -> FleetConfig {
    let spec = traffic_spec(name);
    scenario::lower_fleet(&spec, &Cell::base(&spec), smoke, seed, threads)
}

/// One preset's results: the legacy report plus the windowed series
/// collected from its deterministic trace stream.
struct PresetRun {
    name: String,
    hash: String,
    report: FleetReport,
    series: TimeSeries,
}

fn run_presets(opts: &RunOpts, smoke: bool) -> Result<Vec<PresetRun>> {
    let engine = Arc::new(Engine::builtin());
    let mut out = Vec::new();
    for name in PRESETS {
        let spec = traffic_spec(name);
        let hash = spec.spec_hash();
        let cfg = scenario::lower_fleet(&spec, &Cell::base(&spec), smoke, opts.seed, opts.threads);
        let mut sink = MemorySink::default();
        let report = fleet::run_traced(&engine, &cfg, &mut sink)?;
        let series = timeseries::collect(
            &sink.events,
            report.total_cycles,
            timeseries::DEFAULT_WINDOWS,
            report.chips,
            report.active_chips[0].1,
        );
        out.push(PresetRun { name: name.to_string(), hash, report, series });
    }
    Ok(out)
}

fn traffic_table(results: &[PresetRun]) -> Table {
    let mut t = Table::new(
        "open-loop traffic — offered vs admitted under admission \
         control + autoscaling, metrics in simulated cycles \
         [model: builtin, backend: native]",
        &[
            "scenario",
            "chips",
            "offered",
            "admitted",
            "shed_rate",
            "goodput_per_Mcycle",
            "p99_cycles",
            "slo_attainment",
            "accuracy",
            "scale_steps",
        ],
    );
    for run in results {
        let r = &run.report;
        t.push_row(vec![
            run.name.clone(),
            r.chips.to_string(),
            r.offered.to_string(),
            r.total_requests.to_string(),
            f(r.shed_rate(), 4),
            f(r.goodput_imgs_per_mcycle(), 2),
            r.p99_cycles().to_string(),
            match r.slo_attainment {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            f(r.accuracy, 4),
            (r.active_chips.len() - 1).to_string(),
        ]);
    }
    t
}

fn trajectory_table(name: &str, r: &FleetReport) -> Table {
    let mut t = Table::new(
        format!("{name} — active-chip trajectory (autoscaler steps in simulated cycles)"),
        &["cycle", "active_chips"],
    );
    for (cycle, n) in &r.active_chips {
        t.push_row(vec![cycle.to_string(), n.to_string()]);
    }
    t
}

/// One machine-readable row of `BENCH_traffic.json`. The
/// `active_chips` trajectory is inlined as `[[cycle, n], ...]` so the
/// autoscaler's whole decision history is part of the byte-compared
/// baseline. **Byte-frozen since v1** — the windowed view lives in the
/// separate `timeseries` section.
fn json_row(name: &str, hash: &str, r: &FleetReport, sep: &str) -> String {
    let trajectory: Vec<String> = r
        .active_chips
        .iter()
        .map(|(c, n)| format!("[{c}, {n}]"))
        .collect();
    format!(
        "    {{\"scenario\": \"{name}\", \"spec_hash\": \"{hash}\", \
         \"chips\": {}, \"offered\": {}, \"admitted\": {}, \"shed\": {}, \
         \"shed_rate\": {:.6}, \"goodput_imgs_per_mcycle\": {:.6}, \
         \"p50_cycles\": {}, \"p99_cycles\": {}, \
         \"slo_target_cycles\": {}, \"slo_attainment\": {}, \
         \"accuracy\": {:.6}, \"active_chips\": [{}]}}{sep}\n",
        r.chips,
        r.offered,
        r.total_requests,
        r.shed,
        r.shed_rate(),
        r.goodput_imgs_per_mcycle(),
        r.p50_cycles(),
        r.p99_cycles(),
        r.slo_target_cycles.map_or("null".to_string(), |c| c.to_string()),
        r.slo_attainment.map_or("null".to_string(), |a| format!("{a:.6}")),
        r.accuracy,
        trajectory.join(", "),
    )
}

fn traffic_json(seed: u64, smoke: bool, results: &[PresetRun]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-traffic-bench-v3\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"scenarios\": [\n");
    for (i, run) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&json_row(&run.name, &run.hash, &run.report, sep));
    }
    s.push_str("  ],\n");
    // per-window series from the deterministic trace stream (obs
    // collector, DESIGN.md §10) — same determinism contract as the
    // rows above: a pure function of the seed, byte-identical at any
    // --workers value
    s.push_str("  \"timeseries\": [\n");
    for (i, run) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&timeseries::render_json(&run.series, &run.name, sep));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Full run: report tables + the JSON baseline.
pub fn run_full(opts: &RunOpts, smoke: bool) -> Result<(Vec<Table>, String)> {
    let results = run_presets(opts, smoke)?;
    let json = traffic_json(opts.seed, smoke, &results);
    let mut tables = vec![traffic_table(&results)];
    for run in &results {
        if run.report.active_chips.len() > 1 {
            tables.push(trajectory_table(&run.name, &run.report));
        }
    }
    Ok((tables, json))
}

/// The JSON baseline alone (what `BENCH_traffic.json` holds and the
/// golden test compares across `--workers` values).
pub fn bench_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let results = run_presets(opts, smoke)?;
    Ok(traffic_json(opts.seed, smoke, &results))
}

/// Chrome-trace export of the `flash_crowd` preset — the `--trace`
/// target of `repro traffic`. Shed instants, autoscale decisions,
/// batch spans and chip-lifecycle spans, all in simulated cycles;
/// loadable at ui.perfetto.dev.
pub fn trace_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let cfg = traffic_config("flash_crowd", opts.seed, smoke, opts.threads);
    let mut sink = MemorySink::default();
    let _report = fleet::run_traced(&engine, &cfg, &mut sink)?;
    Ok(trace_export::chrome_trace_json(&sink.events, "traffic/flash_crowd"))
}

impl Experiment for TrafficExp {
    fn id(&self) -> &'static str {
        "traffic"
    }

    fn title(&self) -> &'static str {
        "Traffic: open-loop arrivals — SLO admission control + chip autoscaling"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast)?;
        Ok(tables)
    }
}
