//! Fig. 12 (§V-D): normalised performance of the neural-network
//! benchmark (AlexNet/VGG/YOLO/ResNet) on DLAs protected with the four
//! schemes, normalised to RR, under both fault models. Uses the
//! Scale-sim-analogue perf model memoised over unique surviving-array
//! widths (§V-A3).

use super::{exp_fig10::schemes, Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::montecarlo::FaultModel;
use crate::perfmodel::{mean_normalised_perf, networks, DegradedPerf};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig12;

impl Experiment for Fig12 {
    fn id(&self) -> &'static str {
        "fig12"
    }

    fn title(&self) -> &'static str {
        "Normalized performance (to RR) of the NN benchmark, both fault models"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let dims = Dims::PAPER;
        let nets = networks::benchmark();
        let mut tables = Vec::new();
        for model in FaultModel::both() {
            let mut t = Table::new(
                format!(
                    "Fig.12 ({}) — geo-mean normalized performance vs RR",
                    model.label()
                ),
                &["PER(%)", "net", "RR", "CR", "DR", "HyCA32", "HyCA32_speedup"],
            );
            for per in opts.per_sweep() {
                for net in &nets {
                    let dp = DegradedPerf::new(net, dims);
                    let full = dp.cycles(dims.cols).unwrap();
                    let schemes = schemes();
                    let mut perfs = Vec::new();
                    for s in &schemes {
                        perfs.push(mean_normalised_perf(
                            s.as_ref(),
                            &dp,
                            full,
                            dims,
                            per,
                            model,
                            opts.seed,
                            opts.n_configs(),
                            opts.threads,
                        ));
                    }
                    let rr = perfs[0].max(1e-9);
                    let mut row = vec![f(per * 100.0, 2), net.name.to_string()];
                    for p in &perfs {
                        row.push(f(p / rr, 3));
                    }
                    row.push(f(perfs[3] / rr, 2));
                    t.push_row(row);
                }
            }
            tables.push(t);
        }
        Ok(tables)
    }
}
