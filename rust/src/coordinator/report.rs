//! Report writer: render experiment tables to the console (markdown)
//! and persist CSV under the run's output directory.

use crate::util::table::Table;
use anyhow::{Context, Result};
use std::path::Path;

/// Print tables and write `<out_dir>/<exp_id>_<n>.csv` for each.
pub fn emit(out_dir: &Path, exp_id: &str, tables: &[Table]) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.to_markdown());
        let suffix = if tables.len() > 1 {
            format!("_{i}")
        } else {
            String::new()
        };
        let path = out_dir.join(format!("{exp_id}{suffix}.csv"));
        std::fs::write(&path, t.to_csv())
            .with_context(|| format!("writing {}", path.display()))?;
        println!("(csv: {})\n", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_writes_csv_per_table() {
        let dir = std::env::temp_dir().join(format!("hyca_report_{}", std::process::id()));
        let mut t1 = Table::new("one", &["a"]);
        t1.push(&["1"]);
        let t2 = Table::new("two", &["b"]);
        emit(&dir, "figX", &[t1, t2]).unwrap();
        assert!(dir.join("figX_0.csv").exists());
        assert!(dir.join("figX_1.csv").exists());
        let single = Table::new("solo", &["c"]);
        emit(&dir, "figY", &[single]).unwrap();
        assert!(dir.join("figY.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
