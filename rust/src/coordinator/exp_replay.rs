//! `replay` — event-sourced replay, snapshot/restore and time-travel
//! branching over the cluster engine (`repro replay`, DESIGN.md §12).
//!
//! A fleet scenario runs on [`crate::engine::ClusterEngine`], which
//! appends every state change to a typed event log and captures a full
//! snapshot at every `[engine] snapshot_every_cycles` boundary. This
//! driver then *proves* the event-sourcing contract at runtime, on
//! every invocation:
//!
//! * **resume** — rebuild the engine from the chosen snapshot and
//!   replay to completion; the replayed tail must equal the
//!   uninterrupted log tail event-for-event and the finished timeline
//!   must hash to the same digest (a hard error otherwise);
//! * **fork-free branch** — an empty override set replayed from the
//!   fork must reproduce the base run bit-for-bit before any branch
//!   diff is trusted;
//! * **crash restart** — with `--run-dir`, the log + snapshots persist
//!   to disk; a rerun against a truncated log resumes from the last
//!   usable snapshot, verifies the surviving overlap, heals the log
//!   and emits a `BENCH_replay.json` byte-identical to the
//!   uninterrupted run's.
//!
//! `--branch <file>` replays a `[branch]` override set (kill a chip,
//! rescale the arrival tail) from the fork point and locates the first
//! divergent cycle by folding both event logs through the span ledger
//! ([`crate::obs::attrib::SpanLedger`]) — the same projection `repro
//! audit` prices latency from, so a branch diff and an audit can never
//! disagree about what happened.
//!
//! The baseline (`BENCH_replay.json`, schema `hyca-replay-bench-v1`)
//! holds only integers and the timeline digest — every field compares
//! exactly under `repro diff`, and the bytes are identical whether the
//! run was uninterrupted, resumed in-process, or crash-restarted from
//! disk, at any `--workers` value.

use std::fmt::Write as _;
use std::path::Path;

use super::{Experiment, RunOpts};
use crate::engine::{
    self, branch, project, BranchOverrides, ClusterEngine, Event, Snapshot,
};
use crate::fleet::{FleetConfig, FleetTimeline};
use crate::inference::Engine;
use crate::obs::attrib::AuditReport;
use crate::obs::{recorder, FlightRecorder, NullSink, Probe, SpanLedger};
use crate::scenario::{self, Cell, ScenarioSpec, TrafficMode};
use crate::util::table::Table;
use anyhow::{anyhow, ensure, Context, Result};

pub struct ReplayExp;

/// The canonical replay scenario: a ≥100M-cycle diurnal horizon that
/// is only smoke-runnable *because* of snapshot/resume.
pub const DEFAULT_PRESET: &str = "long_diurnal";

/// Resolve a replay target: a registered preset name or a `.scn` path.
pub fn replay_spec(target: &str) -> Result<ScenarioSpec> {
    if let Some(spec) = scenario::preset(target) {
        return Ok(spec);
    }
    let text = std::fs::read_to_string(target)
        .with_context(|| format!("no preset or readable .scn file named {target:?}"))?;
    Ok(ScenarioSpec::parse(&text)?)
}

/// Lower a replay spec into its runnable [`FleetConfig`] (public so
/// the integration tests run exactly what the bench reports).
pub fn replay_config(spec: &ScenarioSpec, seed: u64, smoke: bool, threads: usize) -> FleetConfig {
    scenario::lower_fleet(spec, &Cell::base(spec), smoke, seed, threads)
}

/// The snapshot cadence: the spec's `[engine] snapshot_every_cycles`
/// knob, or an eighth of the open-loop horizon (closed loop: 20k
/// cycles) when the section is absent.
pub fn snapshot_cadence(spec: &ScenarioSpec, smoke: bool) -> u64 {
    match &spec.engine {
        Some(eng) => *eng.snapshot_every_cycles.at(smoke),
        None => match spec.workload.mode {
            TrafficMode::Open { horizon_cycles, .. } => (horizon_cycles.at(smoke) / 8).max(1),
            TrafficMode::Closed => 20_000,
        },
    }
}

/// FNV-1a over a canonical rendering of everything deterministic in a
/// finished timeline: request records, dispatched batches, the merged
/// cluster event history and the shed log. Two runs are byte-identical
/// iff their digests match (masks are static context recomputed from
/// the config, so they are covered by the batch coordinates).
pub fn timeline_digest(t: &FleetTimeline) -> u64 {
    let mut s = String::with_capacity(128 + 48 * (t.requests.len() + t.jobs.len()));
    let _ = write!(
        s,
        "cycles={};offered={};max_pending={};initial_active={};unrepaired={}",
        t.total_cycles, t.offered, t.max_pending, t.initial_active, t.unrepaired
    );
    for r in &t.requests {
        let _ = write!(
            s,
            ";r{},{},{},{},{},{},{},{}",
            r.id,
            r.client,
            r.image_idx,
            r.enqueue_cycle,
            r.start_cycle,
            r.complete_cycle,
            r.batch_id,
            r.slot
        );
    }
    for j in &t.jobs {
        let _ = write!(
            s,
            ";j{},{},{},{},{}",
            j.chip, j.job.id, j.job.start_cycle, j.job.end_cycle, j.job.lane
        );
        for &ix in &j.job.image_idxs {
            let _ = write!(s, ",{ix}");
        }
    }
    for e in &t.events {
        let (k, a, b) = e.kind.sort_key();
        let _ = write!(s, ";e{},{},{},{},{}", e.cycle, e.chip, k, a, b);
    }
    for c in &t.shed_cycles {
        let _ = write!(s, ";s{c}");
    }
    engine::fnv1a(s.as_bytes())
}

/// One uninterrupted engine run with periodic snapshots: the reference
/// every resume/branch is verified against.
pub struct BaseRun {
    pub snaps: Vec<Snapshot>,
    pub log: Vec<Event>,
    pub timeline: FleetTimeline,
    pub digest: u64,
}

/// Run `cfg` to completion on the cluster engine, snapshotting every
/// `every` cycles.
pub fn run_base(engine: &Engine, cfg: &FleetConfig, every: u64) -> BaseRun {
    let mut rec = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
    let mut sink = NullSink;
    let mut probe = Probe { sink: &mut sink, rec: &mut rec };
    let mut core = ClusterEngine::new(engine, cfg, &mut probe);
    let snaps = core.run_with_snapshots(&mut probe, every);
    let log = core.log().to_vec();
    let timeline = core.finish(&mut probe);
    let digest = timeline_digest(&timeline);
    BaseRun { snaps, log, timeline, digest }
}

/// The in-process resume proof: rebuild from `snap`, replay to the
/// end, and hard-fail unless the replayed tail equals the
/// uninterrupted log tail and the finished timeline hashes to the base
/// digest.
pub fn resume_and_verify(
    engine: &Engine,
    cfg: &FleetConfig,
    snap: &Snapshot,
    base: &BaseRun,
) -> Result<usize> {
    let mut core = ClusterEngine::resume(engine, cfg, snap)
        .map_err(|e| anyhow!("resume from snapshot @{}: {e}", snap.label_cycle))?;
    let mut rec = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
    let mut sink = NullSink;
    let mut probe = Probe { sink: &mut sink, rec: &mut rec };
    core.run(&mut probe);
    let off = snap.events_logged as usize;
    ensure!(
        off <= base.log.len(),
        "snapshot @{} points past the log ({} > {} events)",
        snap.label_cycle,
        off,
        base.log.len()
    );
    ensure!(
        core.log() == &base.log[off..],
        "resume from cycle {} is NOT byte-identical: replayed tail diverges \
         from the uninterrupted event log",
        snap.label_cycle
    );
    let tail_events = core.log().len();
    let timeline = core.finish(&mut probe);
    ensure!(
        timeline_digest(&timeline) == base.digest,
        "resume from cycle {}: timeline digest mismatch vs the uninterrupted run",
        snap.label_cycle
    );
    Ok(tail_events)
}

/// Fold an event log through the span ledger into the audit report the
/// branch diff compares (the exact projection the trace bus carries).
fn ledger_report(cfg: &FleetConfig, events: &[Event], horizon: u64, requests: usize) -> AuditReport {
    let mut ledger = SpanLedger::new(&cfg.lane_counts());
    for e in events {
        ledger.observe(e.cycle, project(e));
    }
    ledger.finish(horizon, &vec![true; requests])
}

/// A branched timeline replayed from a fork snapshot under overrides.
pub struct BranchRun {
    pub fork_cycle: u64,
    pub overrides: BranchOverrides,
    /// Full branched history: shared prefix + replayed tail.
    pub events: Vec<Event>,
    pub timeline: FleetTimeline,
    pub digest: u64,
    /// First cycle where the branch's span ledger disagrees with the
    /// base run's (`None`: timelines identical through the horizon).
    pub divergence: Option<u64>,
}

/// Replay a branch: fork at the latest snapshot at or before the fork
/// cycle, apply the overrides, run to completion, and diff the two
/// timelines through the span ledger. An empty override set is
/// asserted to reproduce the base run bit-for-bit.
pub fn run_branch(
    engine: &Engine,
    cfg: &FleetConfig,
    base: &BaseRun,
    ov: &BranchOverrides,
    from_cycle: Option<u64>,
) -> Result<BranchRun> {
    let fork = ov
        .fork_cycle
        .or(from_cycle)
        .or_else(|| base.snaps.last().map(|s| s.label_cycle))
        .ok_or_else(|| anyhow!("no snapshot to fork from"))?;
    let snap = base
        .snaps
        .iter()
        .rev()
        .find(|s| s.label_cycle <= fork)
        .ok_or_else(|| {
            anyhow!(
                "no snapshot at or before cycle {fork} — first boundary is @{}",
                base.snaps.first().map_or(0, |s| s.label_cycle)
            )
        })?;
    let mut core = ClusterEngine::resume(engine, cfg, snap)
        .map_err(|e| anyhow!("branch fork from snapshot @{}: {e}", snap.label_cycle))?;
    branch::apply(&mut core, ov, fork).map_err(|e| anyhow!("branch overrides: {e}"))?;
    let mut rec = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
    let mut sink = NullSink;
    let mut probe = Probe { sink: &mut sink, rec: &mut rec };
    core.run(&mut probe);
    let off = snap.events_logged as usize;
    let mut events = base.log[..off].to_vec();
    events.extend_from_slice(core.log());
    let timeline = core.finish(&mut probe);
    let digest = timeline_digest(&timeline);
    if ov.is_empty() {
        // the branch identity contract: forking without overrides must
        // reproduce the base run bit-for-bit — asserted before any
        // branch diff is trusted
        ensure!(
            events == base.log && digest == base.digest,
            "fork-free branch replay from cycle {} is NOT byte-identical to the base run",
            snap.label_cycle
        );
    }
    let divergence = engine::first_divergence(
        &ledger_report(cfg, &base.log, base.timeline.total_cycles, base.timeline.requests.len()),
        &ledger_report(cfg, &events, timeline.total_cycles, timeline.requests.len()),
    );
    if ov.is_empty() {
        ensure!(
            divergence.is_none(),
            "fork-free branch reported a divergence at cycle {:?}",
            divergence
        );
    }
    Ok(BranchRun { fork_cycle: fork, overrides: *ov, events, timeline, digest, divergence })
}

/// Persist a base run's artifacts: the framed event log plus one
/// `snap_<cycle>.bin` per snapshot boundary.
pub fn write_artifacts(dir: &Path, base: &BaseRun) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating run dir {}", dir.display()))?;
    std::fs::write(dir.join("events.log"), engine::encode_log(&base.log))
        .context("writing events.log")?;
    for snap in &base.snaps {
        let name = format!("snap_{:012}.bin", snap.label_cycle);
        std::fs::write(dir.join(&name), snap.to_bytes())
            .with_context(|| format!("writing {name}"))?;
    }
    Ok(())
}

/// A crash-restarted run: resumed from on-disk artifacts with a
/// possibly-truncated event log.
pub struct RestartRun {
    pub survived_events: usize,
    pub truncated: bool,
    pub snaps_on_disk: usize,
    pub resumed_from: u64,
    /// Surviving post-snapshot events the replay re-verified.
    pub overlap: usize,
    pub log_events: u64,
    pub timeline: FleetTimeline,
    pub digest: u64,
}

/// Restart from `dir`: decode the longest valid log prefix, pick the
/// latest snapshot the surviving events still cover, replay to
/// completion (verifying the overlap event-for-event) and heal the
/// on-disk log. The finished run is bit-identical to an uninterrupted
/// one, so the bench it produces is too.
pub fn run_restart(engine: &Engine, cfg: &FleetConfig, dir: &Path) -> Result<RestartRun> {
    let bytes = std::fs::read(dir.join("events.log"))
        .with_context(|| format!("reading {}/events.log", dir.display()))?;
    let (events, truncated) = engine::decode_log(&bytes);
    let mut snaps: Vec<Snapshot> = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !(name.starts_with("snap_") && name.ends_with(".bin")) {
            continue;
        }
        match Snapshot::from_bytes(&std::fs::read(entry.path())?) {
            Ok(s) => snaps.push(s),
            // a corrupt snapshot is a degraded restart, not a failed
            // one — the integrity hash caught it, fall back to an
            // earlier boundary
            Err(e) => eprintln!("[repro] replay: skipping corrupt snapshot {name}: {e}"),
        }
    }
    snaps.sort_by_key(|s| s.label_cycle);
    let snaps_on_disk = snaps.len();
    let snap = snaps
        .iter()
        .rev()
        .find(|s| (s.events_logged as usize) <= events.len())
        .ok_or_else(|| {
            anyhow!(
                "no usable snapshot precedes the {} surviving log events — cannot restart",
                events.len()
            )
        })?;
    let mut core = ClusterEngine::resume(engine, cfg, snap)
        .map_err(|e| anyhow!("restart resume from snapshot @{}: {e}", snap.label_cycle))?;
    let mut rec = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
    let mut sink = NullSink;
    let mut probe = Probe { sink: &mut sink, rec: &mut rec };
    core.run(&mut probe);
    let off = snap.events_logged as usize;
    let overlap = &events[off..];
    ensure!(
        core.log().len() >= overlap.len() && &core.log()[..overlap.len()] == overlap,
        "restart replay diverges from the surviving log tail — snapshot @{} does \
         not belong to this event log (wrong seed or config?)",
        snap.label_cycle
    );
    let log_events = core.events_recorded();
    // heal the log: shared prefix + replayed tail is the complete
    // history an uninterrupted run would have written
    let mut full = events[..off].to_vec();
    full.extend_from_slice(core.log());
    std::fs::write(dir.join("events.log"), engine::encode_log(&full))
        .context("rewriting healed events.log")?;
    let timeline = core.finish(&mut probe);
    let digest = timeline_digest(&timeline);
    Ok(RestartRun {
        survived_events: events.len(),
        truncated,
        snaps_on_disk,
        resumed_from: snap.label_cycle,
        overlap: overlap.len(),
        log_events,
        timeline,
        digest,
    })
}

/// The machine-readable baseline: integers and the timeline digest
/// only, so `repro diff` compares every field exactly and the bytes
/// are mode-invariant (uninterrupted, resumed, crash-restarted).
fn bench_json(
    scenario: &str,
    hash: &str,
    seed: u64,
    smoke: bool,
    every: u64,
    tl: &FleetTimeline,
    log_events: u64,
    digest: u64,
) -> String {
    format!(
        "{{\n  \"schema\": \"hyca-replay-bench-v1\",\n  \"scenario\": \"{scenario}\",\n  \
         \"spec_hash\": \"{hash}\",\n  \"seed\": {seed},\n  \"smoke\": {smoke},\n  \
         \"snapshot_every_cycles\": {every},\n  \"total_cycles\": {},\n  \
         \"offered\": {},\n  \"admitted\": {},\n  \"shed\": {},\n  \"batches\": {},\n  \
         \"max_pending\": {},\n  \"log_events\": {log_events},\n  \
         \"digest\": \"{digest:016x}\"\n}}\n",
        tl.total_cycles,
        tl.offered,
        tl.requests.len(),
        tl.shed_cycles.len(),
        tl.jobs.len(),
        tl.max_pending,
    )
}

fn describe(ov: &BranchOverrides) -> String {
    if ov.is_empty() {
        return "identity".to_string();
    }
    let mut parts = Vec::new();
    if let Some((chip, at)) = ov.kill_chip {
        parts.push(format!("kill_chip={chip}@{at}"));
    }
    if let Some(s) = ov.rate_scale {
        parts.push(format!("rate_scale={s}"));
    }
    parts.join(" ")
}

fn verify_table(
    name: &str,
    mode: &str,
    every: u64,
    snapshots: usize,
    resumed_from: u64,
    tail_events: usize,
    log_events: u64,
    tl: &FleetTimeline,
    digest: u64,
) -> Table {
    let mut t = Table::new(
        "replay — snapshot/resume verification (resume + fork-free branch \
         asserted byte-identical at runtime; cycles are simulated)",
        &[
            "scenario",
            "mode",
            "every_cycles",
            "snapshots",
            "resumed_from",
            "tail_events",
            "log_events",
            "total_cycles",
            "admitted",
            "shed",
            "digest",
        ],
    );
    t.push_row(vec![
        name.to_string(),
        mode.to_string(),
        every.to_string(),
        snapshots.to_string(),
        resumed_from.to_string(),
        tail_events.to_string(),
        log_events.to_string(),
        tl.total_cycles.to_string(),
        tl.requests.len().to_string(),
        tl.shed_cycles.len().to_string(),
        format!("{digest:016x}"),
    ]);
    t
}

fn branch_table(runs: &[&BranchRun]) -> Table {
    let mut t = Table::new(
        "time-travel branches — overrides replayed from the fork snapshot, \
         diffed against the base run through the span ledger",
        &["fork_cycle", "overrides", "log_events", "admitted", "shed", "first_divergence"],
    );
    for b in runs {
        t.push_row(vec![
            b.fork_cycle.to_string(),
            describe(&b.overrides),
            b.events.len().to_string(),
            b.timeline.requests.len().to_string(),
            b.timeline.shed_cycles.len().to_string(),
            b.divergence.map_or("-".to_string(), |c| c.to_string()),
        ]);
    }
    t
}

/// The whole `repro replay` pipeline. `branch` carries parsed
/// `[branch]` overrides (the CLI reads the file); `run_dir` switches
/// between persist (fresh) and crash-restart (artifacts present).
pub fn run_cli(
    opts: &RunOpts,
    smoke: bool,
    target: &str,
    from_cycle: Option<u64>,
    branch: Option<BranchOverrides>,
    run_dir: Option<&str>,
) -> Result<(Vec<Table>, String)> {
    let spec = replay_spec(target)?;
    ensure!(
        spec.driver.id() == "fleet",
        "repro replay drives fleet scenarios (got driver {:?} from {target:?})",
        spec.driver.id()
    );
    let hash = spec.spec_hash();
    let cfg = replay_config(&spec, opts.seed, smoke, opts.threads);
    let every = snapshot_cadence(&spec, smoke);
    let engine = Engine::builtin();

    // crash-restart mode: the run dir already holds a (possibly
    // truncated) event log from a previous invocation
    if let Some(dir) = run_dir {
        let dir = Path::new(dir);
        if dir.join("events.log").exists() {
            ensure!(
                branch.is_none(),
                "--branch needs a fresh run — restarting from {} artifacts",
                dir.display()
            );
            let r = run_restart(&engine, &cfg, dir)?;
            eprintln!(
                "[repro] replay: restarted from snapshot @{} ({} of {} surviving \
                 events re-verified{})",
                r.resumed_from,
                r.overlap,
                r.survived_events,
                if r.truncated { ", log was truncated mid-frame" } else { "" }
            );
            let json = bench_json(
                &spec.name, &hash, opts.seed, smoke, every, &r.timeline, r.log_events, r.digest,
            );
            let t = verify_table(
                &spec.name,
                "crash-restart",
                every,
                r.snaps_on_disk,
                r.resumed_from,
                r.overlap,
                r.log_events,
                &r.timeline,
                r.digest,
            );
            return Ok((vec![t], json));
        }
    }

    // fresh run with periodic snapshots
    let base = run_base(&engine, &cfg, every);
    ensure!(
        !base.snaps.is_empty(),
        "run finished before the first snapshot boundary ({every} cycles) — \
         lower [engine] snapshot_every_cycles"
    );

    // the resume proof, from the requested cycle (default: the last
    // snapshot, the longest-lived state)
    let snap = match from_cycle {
        Some(n) => base.snaps.iter().rev().find(|s| s.label_cycle <= n).ok_or_else(|| {
            anyhow!(
                "no snapshot at or before cycle {n} — first boundary is @{}",
                base.snaps[0].label_cycle
            )
        })?,
        None => base.snaps.last().expect("non-empty"),
    };
    let tail_events = resume_and_verify(&engine, &cfg, snap, &base)?;

    // the fork-free branch proof — always on, independent of --branch
    let identity =
        run_branch(&engine, &cfg, &base, &BranchOverrides::default(), Some(snap.label_cycle))?;
    let mut branches = vec![identity];
    if let Some(ov) = branch {
        branches.push(run_branch(&engine, &cfg, &base, &ov, from_cycle)?);
    }

    if let Some(dir) = run_dir {
        write_artifacts(Path::new(dir), &base)?;
        eprintln!(
            "[repro] replay: {} events + {} snapshots persisted to {dir}",
            base.log.len(),
            base.snaps.len()
        );
    }

    let json = bench_json(
        &spec.name,
        &hash,
        opts.seed,
        smoke,
        every,
        &base.timeline,
        base.log.len() as u64,
        base.digest,
    );
    let tables = vec![
        verify_table(
            &spec.name,
            "fresh",
            every,
            base.snaps.len(),
            snap.label_cycle,
            tail_events,
            base.log.len() as u64,
            &base.timeline,
            base.digest,
        ),
        branch_table(&branches.iter().collect::<Vec<_>>()),
    ];
    Ok((tables, json))
}

/// Full run on the default preset, with a demonstration fault branch
/// (chip 0 forced drained at the fork) alongside the always-on resume
/// and identity proofs.
pub fn run_full(opts: &RunOpts, smoke: bool) -> Result<(Vec<Table>, String)> {
    let demo = BranchOverrides { fork_cycle: None, kill_chip: Some((0, 0)), rate_scale: None };
    run_cli(opts, smoke, DEFAULT_PRESET, None, Some(demo), None)
}

/// The JSON baseline alone (what `BENCH_replay.json` holds and the
/// golden test compares across `--workers` values and resume modes).
pub fn bench_json_only(opts: &RunOpts, smoke: bool) -> Result<String> {
    let (_tables, json) = run_cli(opts, smoke, DEFAULT_PRESET, None, None, None)?;
    Ok(json)
}

impl Experiment for ReplayExp {
    fn id(&self) -> &'static str {
        "replay"
    }

    fn title(&self) -> &'static str {
        "Replay: event-sourced engine — snapshot/restore + time-travel branching"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast)?;
        Ok(tables)
    }
}
