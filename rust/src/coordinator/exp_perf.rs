//! `perf` — the wall-clock executor benchmark (`repro perf`): the
//! first experiment whose headline is a *measured* number, not a
//! simulated one (DESIGN.md §8).
//!
//! The grid times five executor plans — the legacy `SharedQueue`, the
//! statically-partitioned `WorkSteal{steal:false}`, work stealing over
//! the PR-5 **mutex** deque, work stealing over the **lock-free**
//! Chase-Lev deque, and lock-free stealing with a 2-wide home set —
//! over `{1,2,4,8}` threads × `{1,4,16}` chips on fleet_default-shaped
//! job mixes (the exact workload `BENCH_fleet.json` reports, lowered
//! through `exp_fleet::fleet_cell`), and writes `BENCH_perf.json`
//! (schema `hyca-perf-bench-v2`; v1 had no deque axis — its
//! `steal_on` rows are v2's `mutex` rows). The mutex-vs-lockfree rows
//! at matching cells are the evidence the lock-free port pays for
//! itself; the home-set row prices the affinity spread.
//!
//! **Determinism split, explicit in the schema:** the `deterministic`
//! section (job/image counts, simulated cycles) is a pure function of
//! the seed and byte-identical everywhere — the same contract as every
//! other bench file, **and byte-frozen across the v1 → v2 schema bump**
//! (the timing section grew rows; the workload descriptions did not
//! change). The `timing` section is wall-clock and therefore
//! **nondeterministic by nature** (machine, load, scheduler); it is
//! marked `"nondeterministic": true` and no determinism lint or golden
//! test ever compares it. Every timed cell re-asserts the invariance
//! contract at runtime: its predictions must equal the 1-thread
//! shared-queue reference bit-for-bit, or the run errors out.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::{exp_fleet, Experiment, RunOpts};
use crate::fleet::{self, RoutingPolicy};
use crate::inference::Engine;
use crate::serve::executor::{self, DequeImpl, ExecMode, ExecPlan};
use crate::serve::BatchJob;
use crate::util::table::{f, Table};

pub struct PerfExp;

/// Executor thread sweep (the `--workers` axis, measured for real).
pub const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Cluster sizes: past-the-core-count is the point (the ROADMAP's
/// scaling-cliff question needs chips > threads).
pub fn chip_sweep(smoke: bool) -> Vec<usize> {
    if smoke {
        vec![1, 4]
    } else {
        vec![1, 4, 16]
    }
}

/// One measured executor plan: mode, deque, home-set width.
#[derive(Debug, Clone, Copy)]
pub struct PlanCell {
    pub mode: ExecMode,
    pub deque: DequeImpl,
    pub home_set: usize,
}

/// The executor plans under measurement, baseline first. `mutex` and
/// `lockfree` differ only in the deque, so their delta at matching
/// cells isolates the cost of the PR-5 mutex; the final row prices
/// home-set spreading on the lock-free deque.
pub fn plan_sweep() -> [PlanCell; 5] {
    [
        PlanCell { mode: ExecMode::SharedQueue, deque: DequeImpl::LockFree, home_set: 1 },
        PlanCell {
            mode: ExecMode::WorkSteal { steal: false },
            deque: DequeImpl::LockFree,
            home_set: 1,
        },
        PlanCell {
            mode: ExecMode::WorkSteal { steal: true },
            deque: DequeImpl::Mutex,
            home_set: 1,
        },
        PlanCell {
            mode: ExecMode::WorkSteal { steal: true },
            deque: DequeImpl::LockFree,
            home_set: 1,
        },
        PlanCell {
            mode: ExecMode::WorkSteal { steal: true },
            deque: DequeImpl::LockFree,
            home_set: 2,
        },
    ]
}

/// Deterministic description of one workload (pure function of the
/// seed — the byte-stable half of the bench file).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DetRow {
    pub chips: usize,
    pub jobs: usize,
    pub images: usize,
    pub total_cycles: u64,
}

/// One timed cell (wall-clock — nondeterministic by nature).
#[derive(Debug, Clone)]
pub struct TimingRow {
    pub chips: usize,
    pub threads: usize,
    pub executor: &'static str,
    pub home_set: usize,
    /// Best-of-reps wall time of one full executor pass.
    pub wall_ms: f64,
    pub jobs_per_sec: f64,
    pub imgs_per_sec: f64,
    /// Steals of the best rep (0 for shared/steal_off).
    pub steals: u64,
}

/// The full perf run: the deterministic workload descriptions plus the
/// timing grid.
pub struct PerfRun {
    pub det: Vec<DetRow>,
    pub timing: Vec<TimingRow>,
}

/// Simulate each chip count's workload once, then time every
/// (threads × plan) cell `reps` times keeping the best wall time.
/// Every cell's predictions are asserted equal to the 1-thread
/// shared-queue reference — the bit-exactness contract, enforced at
/// measurement time.
pub fn run_perf(opts: &RunOpts, smoke: bool, reps: usize) -> Result<PerfRun> {
    let reps = reps.max(1);
    let engine = Arc::new(Engine::builtin());
    let mut det = Vec::new();
    let mut timing = Vec::new();
    for chips in chip_sweep(smoke) {
        let cfg = exp_fleet::fleet_cell(opts.seed, chips, RoutingPolicy::RoundRobin, smoke, 1);
        let timeline = fleet::simulate_fleet(&engine, &cfg);
        let jobs: Vec<&BatchJob> = timeline.jobs.iter().map(|j| &j.job).collect();
        let affinity: Vec<usize> = timeline.jobs.iter().map(|j| j.chip).collect();
        let images: usize = jobs.iter().map(|j| j.image_idxs.len()).sum();
        det.push(DetRow {
            chips,
            jobs: jobs.len(),
            images,
            total_cycles: timeline.total_cycles,
        });
        let reference = executor::execute(
            &engine,
            &jobs,
            None,
            1,
            ExecMode::SharedQueue,
            cfg.queue_cap,
        )?
        .predictions;
        for threads in THREAD_SWEEP {
            for cell in plan_sweep() {
                // the shared queue ignores affinity; the stealing modes
                // home each chip's jobs on the home set at chip % threads
                let aff = match cell.mode {
                    ExecMode::SharedQueue => None,
                    ExecMode::WorkSteal { .. } => Some(affinity.as_slice()),
                };
                let plan = ExecPlan {
                    threads,
                    mode: cell.mode,
                    deque: cell.deque,
                    affinity: aff,
                    home_set: cell.home_set,
                    queue_cap: cfg.queue_cap,
                };
                let mut best_nanos = u128::MAX;
                let mut steals = 0u64;
                for _ in 0..reps {
                    let rep = executor::execute_plan(&engine, &jobs, &plan)?;
                    anyhow::ensure!(
                        rep.predictions == reference,
                        "executor {} (home_set {}) at {} threads diverged from the \
                         1-thread shared-queue reference on the {chips}-chip workload — \
                         the bit-exactness contract is broken",
                        plan.label(),
                        cell.home_set,
                        threads
                    );
                    // wall_ms and steals must describe the SAME rep (the
                    // best one), or the row's steal column misattributes
                    // another rep's scheduling to the reported time
                    if rep.stats.wall_nanos < best_nanos {
                        best_nanos = rep.stats.wall_nanos;
                        steals = rep.stats.steals;
                    }
                }
                let secs = best_nanos as f64 / 1e9;
                timing.push(TimingRow {
                    chips,
                    threads,
                    executor: plan.label(),
                    home_set: cell.home_set,
                    wall_ms: best_nanos as f64 / 1e6,
                    jobs_per_sec: jobs.len() as f64 / secs.max(1e-12),
                    imgs_per_sec: images as f64 / secs.max(1e-12),
                    steals,
                });
            }
        }
    }
    Ok(PerfRun { det, timing })
}

/// The deterministic `grid` section alone — what a byte-comparison
/// across `--workers` values (or repeated runs) may look at. Frozen
/// across the v1 → v2 schema bump: the rendering below is
/// byte-identical to v1's.
pub fn det_json(seed: u64, smoke: bool, det: &[DetRow]) -> String {
    let mut s = String::new();
    s.push_str("  \"deterministic\": {\n");
    s.push_str(&format!("    \"seed\": {seed},\n"));
    s.push_str(&format!("    \"smoke\": {smoke},\n"));
    s.push_str(
        "    \"note\": \"simulated-cycle workload descriptions — pure \
         function of the seed, byte-identical at any thread count\",\n",
    );
    s.push_str("    \"grid\": [\n");
    for (i, d) in det.iter().enumerate() {
        let sep = if i + 1 == det.len() { "" } else { "," };
        s.push_str(&format!(
            "      {{\"chips\": {}, \"jobs\": {}, \"images\": {}, \
             \"total_cycles\": {}}}{sep}\n",
            d.chips, d.jobs, d.images, d.total_cycles
        ));
    }
    s.push_str("    ]\n  }");
    s
}

fn timing_json(timing: &[TimingRow]) -> String {
    let mut s = String::new();
    s.push_str("  \"timing\": {\n");
    s.push_str("    \"nondeterministic\": true,\n");
    s.push_str(
        "    \"note\": \"wall-clock measurements — machine/load/scheduler \
         dependent; never byte-compared, never part of a determinism \
         contract\",\n",
    );
    s.push_str("    \"rows\": [\n");
    for (i, t) in timing.iter().enumerate() {
        let sep = if i + 1 == timing.len() { "" } else { "," };
        s.push_str(&format!(
            "      {{\"chips\": {}, \"threads\": {}, \"executor\": \"{}\", \
             \"home_set\": {}, \"wall_ms\": {:.3}, \"jobs_per_sec\": {:.1}, \
             \"imgs_per_sec\": {:.1}, \"steals\": {}}}{sep}\n",
            t.chips,
            t.threads,
            t.executor,
            t.home_set,
            t.wall_ms,
            t.jobs_per_sec,
            t.imgs_per_sec,
            t.steals
        ));
    }
    s.push_str("    ]\n  }");
    s
}

/// Render `BENCH_perf.json`.
pub fn perf_json(seed: u64, smoke: bool, run: &PerfRun) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-perf-bench-v2\",\n");
    s.push_str(&det_json(seed, smoke, &run.det));
    s.push_str(",\n");
    s.push_str(&timing_json(&run.timing));
    s.push_str("\n}\n");
    s
}

fn perf_table(run: &PerfRun) -> Table {
    let mut t = Table::new(
        "executor wall-clock grid — shared queue vs mutex vs lock-free \
         work stealing (best-of-reps; NONDETERMINISTIC wall time, \
         predictions asserted bit-identical to the 1-thread reference)",
        &[
            "chips",
            "threads",
            "executor",
            "home_set",
            "wall_ms",
            "jobs_per_sec",
            "imgs_per_sec",
            "steals",
            "speedup_vs_shared",
        ],
    );
    for row in &run.timing {
        let shared_ms = run
            .timing
            .iter()
            .find(|r| r.chips == row.chips && r.threads == row.threads && r.executor == "shared")
            .map(|r| r.wall_ms)
            .unwrap_or(row.wall_ms);
        t.push_row(vec![
            row.chips.to_string(),
            row.threads.to_string(),
            row.executor.to_string(),
            row.home_set.to_string(),
            f(row.wall_ms, 3),
            f(row.jobs_per_sec, 1),
            f(row.imgs_per_sec, 1),
            row.steals.to_string(),
            format!("{}x", f(shared_ms / row.wall_ms.max(1e-12), 2)),
        ]);
    }
    t
}

fn workload_table(run: &PerfRun) -> Table {
    let mut t = Table::new(
        "perf workloads — fleet_default-shaped job mixes (deterministic: \
         pure function of the seed)",
        &["chips", "jobs", "images", "total_cycles"],
    );
    for d in &run.det {
        t.push_row(vec![
            d.chips.to_string(),
            d.jobs.to_string(),
            d.images.to_string(),
            d.total_cycles.to_string(),
        ]);
    }
    t
}

/// Full run: tables + the `BENCH_perf.json` payload.
pub fn run_full(opts: &RunOpts, smoke: bool) -> Result<(Vec<Table>, String)> {
    let reps = if smoke { 2 } else { 3 };
    let run = run_perf(opts, smoke, reps)?;
    let json = perf_json(opts.seed, smoke, &run);
    Ok((vec![workload_table(&run), perf_table(&run)], json))
}

impl Experiment for PerfExp {
    fn id(&self) -> &'static str {
        "perf"
    }

    fn title(&self) -> &'static str {
        "Perf: wall-clock executor grid — shared queue vs mutex vs lock-free stealing, threads × chips"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let t0 = Instant::now();
        let (tables, _json) = run_full(opts, opts.fast)?;
        eprintln!(
            "[repro] perf grid measured in {:.1}s (timing is wall-clock; \
             run `repro perf` from the repo root to persist BENCH_perf.json)",
            t0.elapsed().as_secs_f64()
        );
        Ok(tables)
    }
}
