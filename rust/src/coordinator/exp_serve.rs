//! `serve` — the serving-subsystem experiment (`repro serve`): a
//! throughput/latency grid over simulated worker lanes × dynamic batch
//! sizes, plus the online scan-and-repair scenario with mid-run fault
//! arrivals.
//!
//! Always runs on the **builtin** engine: the exact-recovery contract
//! (accuracy returns to exactly 1.0 after remap) only holds for the
//! synthetic eval set whose labels are the clean argmax, and the
//! machine-readable perf baseline (`BENCH_serve.json`) must never
//! depend on local artifact state.
//!
//! Determinism contract (asserted by `rust/tests/serve.rs`): the JSON
//! and every table are byte-identical for a given master seed at any
//! `--workers` / `--threads` value — the executor width only selects
//! how many real threads crunch the math; all metrics live in
//! simulated cycles. EXPERIMENTS.md documents the regen command.

use std::sync::Arc;

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::inference::Engine;
use crate::serve::metrics::ServeReport;
use crate::serve::scan_agent::EventKind;
use crate::serve::{self, FaultPlan, ServeConfig};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct ServeExp;

/// Full grid: simulated worker lanes × dynamic batch cap.
pub const GRID_LANES: [usize; 4] = [1, 2, 4, 8];
pub const GRID_BATCH: [usize; 3] = [1, 8, 32];
/// Reduced grid for `--smoke` / `--fast` (CI).
pub const SMOKE_LANES: [usize; 2] = [1, 4];
pub const SMOKE_BATCH: [usize; 2] = [1, 8];

fn grid(smoke: bool) -> Vec<(usize, usize)> {
    let (lanes, batches): (&[usize], &[usize]) = if smoke {
        (&SMOKE_LANES, &SMOKE_BATCH)
    } else {
        (&GRID_LANES, &GRID_BATCH)
    };
    let mut cells = Vec::new();
    for &l in lanes {
        for &b in batches {
            cells.push((l, b));
        }
    }
    cells
}

/// One fault-free grid cell. Clients scale with capacity so every
/// lane stays saturated and the comparison isolates batching/lanes.
/// Public so `benches/serve_throughput.rs` measures exactly the
/// workload BENCH_serve.json reports.
pub fn grid_cell(
    seed: u64,
    lanes: usize,
    max_batch: usize,
    smoke: bool,
    threads: usize,
) -> ServeConfig {
    let clients = (lanes * max_batch * 2).max(4);
    ServeConfig {
        seed,
        dims: Dims::new(8, 8), // same model:array ratio as fig2
        lanes,
        max_batch,
        max_wait_cycles: 8_000,
        clients,
        think_cycles: 500,
        total_requests: if smoke { 64 } else { 192 },
        queue_cap: clients,
        executor_threads: threads,
        windows: 4,
        faults: None,
    }
}

/// The mid-run fault scenario: dip → scan detection → live remap →
/// exact recovery.
pub fn scenario_config(seed: u64, smoke: bool, threads: usize) -> ServeConfig {
    ServeConfig {
        seed,
        dims: Dims::new(8, 8),
        lanes: 2,
        max_batch: 8,
        max_wait_cycles: 8_000,
        clients: 16,
        think_cycles: 500,
        total_requests: if smoke { 96 } else { 384 },
        queue_cap: 16,
        executor_threads: threads,
        windows: 10,
        faults: Some(FaultPlan {
            mean_interarrival_cycles: if smoke { 20_000.0 } else { 60_000.0 },
            horizon_cycles: if smoke { 60_000 } else { 200_000 },
            scan_period_cycles: if smoke { 4_000 } else { 16_000 },
            group_width: 8,
            fpt_capacity: 8,
            max_arrivals: 6,
        }),
    }
}

fn run_grid(
    engine: &Arc<Engine>,
    opts: &RunOpts,
    smoke: bool,
) -> Result<Vec<(usize, usize, ServeReport)>> {
    let mut out = Vec::new();
    for (lanes, max_batch) in grid(smoke) {
        let cfg = grid_cell(opts.seed, lanes, max_batch, smoke, opts.threads);
        let report = serve::run(engine, &cfg)?;
        out.push((lanes, max_batch, report));
    }
    Ok(out)
}

fn grid_table(results: &[(usize, usize, ServeReport)]) -> Table {
    let mut t = Table::new(
        "serve grid — throughput and latency in simulated cycles \
         [model: builtin, backend: native]",
        &[
            "workers",
            "max_batch",
            "requests",
            "batches",
            "mean_batch",
            "imgs_per_Mcycle",
            "p50_cycles",
            "p99_cycles",
            "accuracy",
        ],
    );
    for (lanes, max_batch, r) in results {
        t.push_row(vec![
            lanes.to_string(),
            max_batch.to_string(),
            r.total_requests.to_string(),
            r.batches.to_string(),
            f(r.mean_batch_size, 2),
            f(r.throughput_imgs_per_mcycle, 2),
            r.p50_cycles().to_string(),
            r.p99_cycles().to_string(),
            f(r.accuracy, 4),
        ]);
    }
    t
}

/// Render the machine-readable perf baseline. Wall-clock fields are
/// deliberately absent: everything is simulated cycles and therefore
/// reproducible byte-for-byte from the seed.
fn grid_json(seed: u64, smoke: bool, results: &[(usize, usize, ServeReport)]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-serve-bench-v1\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"grid\": [\n");
    for (i, (lanes, max_batch, r)) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"workers\": {lanes}, \"max_batch\": {max_batch}, \
             \"requests\": {}, \"batches\": {}, \
             \"throughput_imgs_per_mcycle\": {:.6}, \
             \"p50_cycles\": {}, \"p99_cycles\": {}}}{sep}\n",
            r.total_requests,
            r.batches,
            r.throughput_imgs_per_mcycle,
            r.p50_cycles(),
            r.p99_cycles(),
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn scenario_table(report: &ServeReport) -> Table {
    let mut t = Table::new(
        "serve under mid-run faults — accuracy timeline \
         (windows in simulated cycles)",
        &["window", "start", "end", "requests", "accuracy", "events"],
    );
    let last_index = report.windows.len().saturating_sub(1);
    for w in &report.windows {
        // scans keep running after traffic ends, so a late detection can
        // land past the final window — fold it into the last row rather
        // than silently dropping it (the summary table counts it too)
        let evs: Vec<String> = report
            .events
            .iter()
            .filter(|e| {
                e.cycle >= w.start_cycle && (e.cycle < w.end_cycle || w.index == last_index)
            })
            .map(|e| match e.kind {
                EventKind::FaultArrival(c) => format!("fault@({},{})", c.row, c.col),
                EventKind::ScanDetection(c) => format!("remap@({},{})", c.row, c.col),
            })
            .collect();
        t.push_row(vec![
            w.index.to_string(),
            w.start_cycle.to_string(),
            w.end_cycle.to_string(),
            w.requests.to_string(),
            match w.accuracy() {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            if evs.is_empty() { "-".to_string() } else { evs.join(" ") },
        ]);
    }
    t
}

fn scenario_summary(report: &ServeReport) -> Table {
    let arrivals = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::FaultArrival(_)))
        .count();
    let detections = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::ScanDetection(_)))
        .count();
    let recovered = report.unrepaired == 0 && report.final_window_accuracy() == Some(1.0);
    let mut t = Table::new(
        "serve scenario summary",
        &["metric", "value"],
    );
    t.push_row(vec!["fault_arrivals".into(), arrivals.to_string()]);
    t.push_row(vec!["scan_detections".into(), detections.to_string()]);
    t.push_row(vec!["unrepaired".into(), report.unrepaired.to_string()]);
    t.push_row(vec!["overall_accuracy".into(), f(report.accuracy, 4)]);
    t.push_row(vec![
        "final_window_accuracy".into(),
        match report.final_window_accuracy() {
            Some(a) => f(a, 4),
            None => "-".to_string(),
        },
    ]);
    t.push_row(vec!["recovered_exactly".into(), recovered.to_string()]);
    t
}

/// Grid + scenario; returns the report tables and the JSON baseline.
pub fn run_full(opts: &RunOpts, smoke: bool) -> Result<(Vec<Table>, String)> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke)?;
    let json = grid_json(opts.seed, smoke, &grid_results);
    let scenario = serve::run(&engine, &scenario_config(opts.seed, smoke, opts.threads))?;
    let tables = vec![
        grid_table(&grid_results),
        scenario_table(&scenario),
        scenario_summary(&scenario),
    ];
    Ok((tables, json))
}

/// The JSON baseline alone (what `BENCH_serve.json` holds and the
/// golden test compares across `--workers` values).
pub fn bench_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke)?;
    Ok(grid_json(opts.seed, smoke, &grid_results))
}

/// The fault scenario alone (used by `rust/tests/serve.rs`).
pub fn scenario_report(opts: &RunOpts, smoke: bool) -> Result<ServeReport> {
    let engine = Arc::new(Engine::builtin());
    serve::run(&engine, &scenario_config(opts.seed, smoke, opts.threads))
}

impl Experiment for ServeExp {
    fn id(&self) -> &'static str {
        "serve"
    }

    fn title(&self) -> &'static str {
        "Serving: lanes×batch throughput grid + online scan-and-repair under mid-run faults"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast)?;
        Ok(tables)
    }
}
