//! `serve` — the serving-subsystem experiment (`repro serve`): a
//! throughput/latency grid over simulated worker lanes × dynamic batch
//! sizes, plus the online scan-and-repair scenario with mid-run fault
//! arrivals.
//!
//! This driver is *thin*: it owns no experiment configuration. The
//! grid is the `steady_state` scenario preset and the fault scenario
//! is the `burst` preset (`crate::scenario::presets`); both lower
//! into [`ServeConfig`]s through `scenario::lower`, so `repro serve`
//! and `repro scenario steady_state` are the same computation — the
//! compatibility bar `rust/tests/scenario.rs` pins byte-exactly.
//!
//! Always runs on the **builtin** engine: the exact-recovery contract
//! (accuracy returns to exactly 1.0 after remap) only holds for the
//! synthetic eval set whose labels are the clean argmax, and the
//! machine-readable perf baseline (`BENCH_serve.json`) must never
//! depend on local artifact state.
//!
//! Determinism contract (asserted by `rust/tests/serve.rs`): the JSON
//! and every table are byte-identical for a given master seed at any
//! `--workers` / `--threads` value — the executor width only selects
//! how many real threads crunch the math; all metrics live in
//! simulated cycles. EXPERIMENTS.md documents the regen command.

use std::sync::Arc;

use super::{Experiment, RunOpts};
use crate::inference::Engine;
use crate::scenario::{self, Cell, ScenarioSpec};
use crate::serve::metrics::ServeReport;
use crate::serve::scan_agent::EventKind;
use crate::serve::{self, ServeConfig};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct ServeExp;

fn steady_state() -> ScenarioSpec {
    scenario::preset("steady_state").expect("steady_state preset is registered")
}

fn burst() -> ScenarioSpec {
    scenario::preset("burst").expect("burst preset is registered")
}

/// One fault-free grid cell, lowered from the `steady_state` preset
/// (clients scale with capacity so every lane stays saturated and the
/// comparison isolates batching/lanes). Public so
/// `benches/serve_throughput.rs` measures exactly the workload
/// `BENCH_serve.json` reports.
pub fn grid_cell(
    seed: u64,
    lanes: usize,
    max_batch: usize,
    smoke: bool,
    threads: usize,
) -> ServeConfig {
    let spec = steady_state();
    let cell = Cell::base(&spec).with_lanes(lanes).with_max_batch(max_batch);
    scenario::lower_serve(&spec, &cell, smoke, seed, threads)
        .expect("steady_state cells are serve-shaped")
}

/// The mid-run fault scenario (dip → scan detection → live remap →
/// exact recovery), lowered from the `burst` preset.
pub fn scenario_config(seed: u64, smoke: bool, threads: usize) -> ServeConfig {
    let spec = burst();
    scenario::lower_serve(&spec, &Cell::base(&spec), smoke, seed, threads)
        .expect("burst is serve-shaped")
}

fn run_grid(
    engine: &Arc<Engine>,
    opts: &RunOpts,
    smoke: bool,
) -> Result<Vec<(usize, usize, ServeReport)>> {
    let spec = steady_state();
    let mut out = Vec::new();
    for cell in spec.cells(smoke) {
        let cfg = scenario::lower_serve(&spec, &cell, smoke, opts.seed, opts.threads)?;
        let (lanes, max_batch) = (cfg.lanes, cfg.max_batch);
        let report = serve::run(engine, &cfg)?;
        out.push((lanes, max_batch, report));
    }
    Ok(out)
}

pub(crate) fn grid_table(results: &[(usize, usize, ServeReport)]) -> Table {
    let mut t = Table::new(
        "serve grid — throughput and latency in simulated cycles \
         [model: builtin, backend: native]",
        &[
            "workers",
            "max_batch",
            "requests",
            "batches",
            "mean_batch",
            "imgs_per_Mcycle",
            "p50_cycles",
            "p99_cycles",
            "accuracy",
        ],
    );
    for (lanes, max_batch, r) in results {
        t.push_row(vec![
            lanes.to_string(),
            max_batch.to_string(),
            r.total_requests.to_string(),
            r.batches.to_string(),
            f(r.mean_batch_size, 2),
            f(r.throughput_imgs_per_mcycle, 2),
            r.p50_cycles().to_string(),
            r.p99_cycles().to_string(),
            f(r.accuracy, 4),
        ]);
    }
    t
}

/// One machine-readable grid row — the byte-stable serve bench row
/// format shared by `BENCH_serve.json` and scenario bench files.
pub(crate) fn json_row(lanes: usize, max_batch: usize, r: &ServeReport, sep: &str) -> String {
    format!(
        "    {{\"workers\": {lanes}, \"max_batch\": {max_batch}, \
         \"requests\": {}, \"batches\": {}, \
         \"throughput_imgs_per_mcycle\": {:.6}, \
         \"p50_cycles\": {}, \"p99_cycles\": {}}}{sep}\n",
        r.total_requests,
        r.batches,
        r.throughput_imgs_per_mcycle,
        r.p50_cycles(),
        r.p99_cycles(),
    )
}

/// Render the machine-readable perf baseline. Wall-clock fields are
/// deliberately absent: everything is simulated cycles and therefore
/// reproducible byte-for-byte from the seed.
fn grid_json(seed: u64, smoke: bool, results: &[(usize, usize, ServeReport)]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"hyca-serve-bench-v1\",\n");
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str("  \"grid\": [\n");
    for (i, (lanes, max_batch, r)) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&json_row(*lanes, *max_batch, r, sep));
    }
    s.push_str("  ]\n}\n");
    s
}

pub(crate) fn scenario_table(report: &ServeReport) -> Table {
    let mut t = Table::new(
        "serve under mid-run faults — accuracy timeline \
         (windows in simulated cycles)",
        &["window", "start", "end", "requests", "accuracy", "events"],
    );
    let last_index = report.windows.len().saturating_sub(1);
    for w in &report.windows {
        // scans keep running after traffic ends, so a late detection can
        // land past the final window — fold it into the last row rather
        // than silently dropping it (the summary table counts it too)
        let evs: Vec<String> = report
            .events
            .iter()
            .filter(|e| {
                e.cycle >= w.start_cycle && (e.cycle < w.end_cycle || w.index == last_index)
            })
            .map(|e| match e.kind {
                EventKind::FaultArrival(c) => format!("fault@({},{})", c.row, c.col),
                EventKind::ScanDetection(c) => format!("remap@({},{})", c.row, c.col),
            })
            .collect();
        t.push_row(vec![
            w.index.to_string(),
            w.start_cycle.to_string(),
            w.end_cycle.to_string(),
            w.requests.to_string(),
            match w.accuracy() {
                Some(a) => f(a, 4),
                None => "-".to_string(),
            },
            if evs.is_empty() { "-".to_string() } else { evs.join(" ") },
        ]);
    }
    t
}

pub(crate) fn scenario_summary(report: &ServeReport) -> Table {
    let arrivals = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::FaultArrival(_)))
        .count();
    let detections = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::ScanDetection(_)))
        .count();
    let recovered = report.unrepaired == 0 && report.final_window_accuracy() == Some(1.0);
    let mut t = Table::new(
        "serve scenario summary",
        &["metric", "value"],
    );
    t.push_row(vec!["fault_arrivals".into(), arrivals.to_string()]);
    t.push_row(vec!["scan_detections".into(), detections.to_string()]);
    t.push_row(vec!["unrepaired".into(), report.unrepaired.to_string()]);
    t.push_row(vec!["overall_accuracy".into(), f(report.accuracy, 4)]);
    t.push_row(vec![
        "final_window_accuracy".into(),
        match report.final_window_accuracy() {
            Some(a) => f(a, 4),
            None => "-".to_string(),
        },
    ]);
    t.push_row(vec!["recovered_exactly".into(), recovered.to_string()]);
    t
}

/// Grid + scenario; returns the report tables and the JSON baseline.
pub fn run_full(opts: &RunOpts, smoke: bool) -> Result<(Vec<Table>, String)> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke)?;
    let json = grid_json(opts.seed, smoke, &grid_results);
    let scenario = serve::run(&engine, &scenario_config(opts.seed, smoke, opts.threads))?;
    let tables = vec![
        grid_table(&grid_results),
        scenario_table(&scenario),
        scenario_summary(&scenario),
    ];
    Ok((tables, json))
}

/// The JSON baseline alone (what `BENCH_serve.json` holds and the
/// golden test compares across `--workers` values).
pub fn bench_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let grid_results = run_grid(&engine, opts, smoke)?;
    Ok(grid_json(opts.seed, smoke, &grid_results))
}

/// Chrome-trace export of the `burst` fault scenario — the `--trace`
/// target of `repro serve` (request spans, batch spans, fault/scan/
/// remap instants on chip 0's fault track, in simulated cycles;
/// loadable at ui.perfetto.dev).
pub fn trace_json(opts: &RunOpts, smoke: bool) -> Result<String> {
    let engine = Arc::new(Engine::builtin());
    let cfg = scenario_config(opts.seed, smoke, opts.threads);
    let mut sink = crate::obs::MemorySink::default();
    let _report = serve::run_traced(&engine, &cfg, &mut sink)?;
    Ok(crate::obs::trace_export::chrome_trace_json(&sink.events, "serve/burst"))
}

/// The fault scenario alone (used by `rust/tests/serve.rs`).
pub fn scenario_report(opts: &RunOpts, smoke: bool) -> Result<ServeReport> {
    let engine = Arc::new(Engine::builtin());
    serve::run(&engine, &scenario_config(opts.seed, smoke, opts.threads))
}

impl Experiment for ServeExp {
    fn id(&self) -> &'static str {
        "serve"
    }

    fn title(&self) -> &'static str {
        "Serving: lanes×batch throughput grid + online scan-and-repair under mid-run faults"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let (tables, _json) = run_full(opts, opts.fast)?;
        Ok(tables)
    }
}
