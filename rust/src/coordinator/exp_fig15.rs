//! Fig. 15 (§V-E): DPPU structure scalability — unified vs grouped
//! DPPU at sizes 16/24/32/40/48 on the 32×32 array. The grouped
//! structure's FFP cliff tracks the DPPU size exactly; the unified
//! structure plateaus at the register-file alignment (capacity 16 for
//! size 24, 32 for sizes 40/48).

use super::{Experiment, RunOpts};
use crate::array::Dims;
use crate::faults::montecarlo::FaultModel;
use crate::redundancy::{evaluate_scheme, hyca::HycaScheme};
use crate::util::table::{f, Table};
use anyhow::Result;

pub struct Fig15;

pub const DPPU_SIZES: [usize; 5] = [16, 24, 32, 40, 48];

impl Experiment for Fig15 {
    fn id(&self) -> &'static str {
        "fig15"
    }

    fn title(&self) -> &'static str {
        "FFP of unified vs grouped DPPU at sizes 16-48, both fault models"
    }

    fn run(&self, opts: &RunOpts) -> Result<Vec<Table>> {
        let dims = Dims::PAPER;
        let mut tables = Vec::new();
        for model in FaultModel::both() {
            let mut cols = vec!["PER(%)".to_string()];
            for s in DPPU_SIZES {
                cols.push(format!("G{s}"));
                cols.push(format!("U{s}"));
            }
            let mut t = Table::new(
                format!(
                    "Fig.15 ({}) — FFP, Grouped (G) vs Unified (U) DPPU",
                    model.label()
                ),
                &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            );
            for per in opts.per_sweep() {
                let mut row = vec![f(per * 100.0, 2)];
                for size in DPPU_SIZES {
                    for scheme in [HycaScheme::paper(size), HycaScheme::unified(size)] {
                        let (ffp, _) = evaluate_scheme(
                            &scheme,
                            dims,
                            per,
                            model,
                            opts.seed,
                            opts.n_configs(),
                            opts.threads,
                        );
                        row.push(f(ffp, 4));
                    }
                }
                t.push_row(row);
            }
            tables.push(t);
        }
        Ok(tables)
    }
}
