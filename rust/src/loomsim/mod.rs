//! # loomsim — in-repo loom-style exhaustive interleaving exploration
//!
//! The lock-free executor hot path (`serve::deque`, `serve::slot`,
//! DESIGN.md §8) deletes the mutexes PR 5 left around the Chase-Lev
//! deques. Deleting a mutex is only safe *after* the protocol is
//! proved, and the ROADMAP names "loom-style interleaving exploration"
//! as the proof vehicle. This crate is dependency-free by policy, so
//! instead of the external `loom` crate this module implements the
//! same idea from scratch:
//!
//! * [`model`] runs a closure **once per schedule** until every
//!   sequentially-consistent interleaving of its threads has been
//!   explored. Threads are real OS threads, but only one runs at a
//!   time: every instrumented operation is a *yield point* where the
//!   scheduler picks which thread steps next.
//! * [`atomic`] provides instrumented `AtomicUsize`/`AtomicIsize`/…
//!   wrappers and [`atomic::fence`]; [`cell::UnsafeCell`] marks
//!   non-atomic payload accesses. Outside a model run they pass
//!   straight through to `std` (one thread-local check), so the same
//!   code path is exercised in ordinary tests.
//! * [`sync`] is the facade the production code compiles against:
//!   plain `std::sync::atomic` types in release builds (zero cost),
//!   the instrumented wrappers under `cfg(any(test, loom))` — so the
//!   deque/slot proofs run inside plain `cargo test` *and* as the
//!   dedicated `--cfg loom` CI job (`rust/tests/loom_executor.rs`).
//!
//! Exploration is a depth-first search over scheduler decisions: each
//! run records, at every yield point, which runnable thread was picked
//! out of how many; the next run replays the deepest prefix with an
//! untried alternative. Same program + same choices ⇒ same state, so
//! the search is exhaustive for deterministic closures. A failed
//! assertion aborts the search and re-panics **with the schedule
//! trace**, which is the counterexample.
//!
//! **Scope honesty.** This explores every interleaving at atomic-op
//! granularity under *sequential consistency*. It proves the protocol
//! logic — the steal/pop boundary race, slot-reuse ABA across ring
//! wrap-around, the one-shot result-slot race — but it cannot observe
//! weak-memory reorderings, so the `Acquire`/`Release` pairings are
//! argued in DESIGN.md §8 (orderings table) rather than model-checked.
//! That matches what the mutex deletion needs: the mutexes never
//! provided more than SC over the same critical sections.

pub mod atomic;
pub mod cell;
mod sched;
pub mod sync;
pub mod thread;

pub use sched::{active, model, model_bounded, Explored};
