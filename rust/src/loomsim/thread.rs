//! Model-aware `thread::spawn`/`join`.
//!
//! Inside a [`crate::loomsim::model`] run, spawned closures become
//! *model threads*: real OS threads registered with the session, gated
//! so only the baton holder executes, with `join` expressed as a
//! scheduler-visible blocked state (a happens-before edge the explorer
//! respects). Outside a model run everything passes straight through
//! to `std::thread`, so the same test helper works in ordinary stress
//! tests.

use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Mutex};

use super::sched;

enum Inner<T> {
    /// A thread of an active exploration session.
    Model {
        sess: Arc<sched::Session>,
        tid: usize,
        result: Arc<Mutex<Option<T>>>,
    },
    /// Plain OS thread (spawned outside any model run).
    Os(std::thread::JoinHandle<T>),
}

pub struct JoinHandle<T> {
    inner: Inner<T>,
}

/// Spawn a thread. Under a model run the child is registered with the
/// session and starts parked; it takes its first step only when the
/// scheduler grants it the baton.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match sched::current() {
        None => JoinHandle { inner: Inner::Os(std::thread::spawn(f)) },
        Some((sess, _me)) => {
            let tid = sess.register();
            let result: Arc<Mutex<Option<T>>> = Arc::new(Mutex::new(None));
            let sink = Arc::clone(&result);
            let child_sess = Arc::clone(&sess);
            let h = std::thread::spawn(move || {
                let body = AssertUnwindSafe(move || {
                    let v = f();
                    *sink.lock().unwrap() = Some(v);
                });
                sched::run_controlled(child_sess, tid, body);
            });
            sess.set_handle(tid, h);
            JoinHandle { inner: Inner::Model { sess, tid, result } }
        }
    }
}

impl<T> JoinHandle<T> {
    /// Wait for the thread and return its value. Model join is a
    /// scheduler-visible block: the caller is unrunnable until the
    /// target finishes, then resumes when granted the baton.
    pub fn join(self) -> T {
        match self.inner {
            Inner::Os(h) => h.join().expect("loomsim: joined thread panicked"),
            Inner::Model { sess, tid, result } => {
                let (_, me) = sched::current()
                    .expect("loomsim: model JoinHandle joined from outside its model run");
                sess.join_wait(me, tid);
                result
                    .lock()
                    .unwrap()
                    .take()
                    .expect("loomsim: joined model thread panicked before producing a value")
            }
        }
    }
}
