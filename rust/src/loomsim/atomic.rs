//! Instrumented atomics: every operation is a scheduler yield point.
//!
//! Each wrapper delegates to the matching `std::sync::atomic` type;
//! the only addition is a call to the session yield point *before* the
//! operation, which is what lets the explorer serialize threads at
//! atomic-op granularity. Outside a model run the yield point is a
//! single thread-local read, so these types are usable (cheaply) in
//! ordinary tests too.
//!
//! `compare_exchange_weak` maps to the strong variant: the model
//! explores interleavings, not spurious LL/SC failures — a weak CAS
//! used in a retry loop behaves identically under that lens.

use std::sync::atomic::Ordering;

use super::sched::yield_point;

macro_rules! instrumented_atomic {
    ($name:ident, $std:ty, $val:ty) => {
        #[derive(Debug, Default)]
        pub struct $name($std);

        impl $name {
            pub const fn new(v: $val) -> Self {
                Self(<$std>::new(v))
            }

            pub fn into_inner(self) -> $val {
                self.0.into_inner()
            }

            pub fn load(&self, order: Ordering) -> $val {
                yield_point();
                self.0.load(order)
            }

            pub fn store(&self, v: $val, order: Ordering) {
                yield_point();
                self.0.store(v, order)
            }

            pub fn swap(&self, v: $val, order: Ordering) -> $val {
                yield_point();
                self.0.swap(v, order)
            }

            pub fn compare_exchange(
                &self,
                current: $val,
                new: $val,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$val, $val> {
                yield_point();
                self.0.compare_exchange(current, new, success, failure)
            }

            pub fn compare_exchange_weak(
                &self,
                current: $val,
                new: $val,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$val, $val> {
                yield_point();
                self.0.compare_exchange(current, new, success, failure)
            }
        }
    };
}

macro_rules! instrumented_int_ops {
    ($name:ident, $val:ty) => {
        impl $name {
            pub fn fetch_add(&self, v: $val, order: Ordering) -> $val {
                yield_point();
                self.0.fetch_add(v, order)
            }

            pub fn fetch_sub(&self, v: $val, order: Ordering) -> $val {
                yield_point();
                self.0.fetch_sub(v, order)
            }

            pub fn fetch_max(&self, v: $val, order: Ordering) -> $val {
                yield_point();
                self.0.fetch_max(v, order)
            }
        }
    };
}

instrumented_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
instrumented_atomic!(AtomicIsize, std::sync::atomic::AtomicIsize, isize);
instrumented_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
instrumented_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
instrumented_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);

instrumented_int_ops!(AtomicUsize, usize);
instrumented_int_ops!(AtomicIsize, isize);
instrumented_int_ops!(AtomicU32, u32);
instrumented_int_ops!(AtomicU64, u64);

impl AtomicBool {
    pub fn fetch_or(&self, v: bool, order: Ordering) -> bool {
        yield_point();
        self.0.fetch_or(v, order)
    }
}

#[derive(Debug)]
pub struct AtomicPtr<T>(std::sync::atomic::AtomicPtr<T>);

impl<T> AtomicPtr<T> {
    pub const fn new(p: *mut T) -> Self {
        Self(std::sync::atomic::AtomicPtr::new(p))
    }

    pub fn load(&self, order: Ordering) -> *mut T {
        yield_point();
        self.0.load(order)
    }

    pub fn store(&self, p: *mut T, order: Ordering) {
        yield_point();
        self.0.store(p, order)
    }

    pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
        yield_point();
        self.0.swap(p, order)
    }

    pub fn compare_exchange(
        &self,
        current: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        yield_point();
        self.0.compare_exchange(current, new, success, failure)
    }
}

/// Instrumented memory fence — a yield point, then the real fence.
pub fn fence(order: Ordering) {
    yield_point();
    std::sync::atomic::fence(order)
}
