//! Instrumented `UnsafeCell`: non-atomic payload accesses become
//! yield points too, so the explorer can interleave a thief's payload
//! read against the owner's overwrite — the exact hazard window the
//! Chase-Lev top-CAS exists to close.
//!
//! The API is access-scoped (`with`/`with_mut` instead of a bare
//! `get`) so every dereference site is visible in the source and
//! yields exactly once.

use super::sched::yield_point;

#[derive(Debug, Default)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

impl<T> UnsafeCell<T> {
    pub const fn new(v: T) -> Self {
        Self(std::cell::UnsafeCell::new(v))
    }

    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }

    /// Immutable access to the payload pointer.
    ///
    /// # Safety contract (caller)
    /// The closure must not dereference the pointer beyond the
    /// protocol's published bounds — same rules as a raw
    /// `UnsafeCell::get`.
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        yield_point();
        f(self.0.get() as *const T)
    }

    /// Mutable access to the payload pointer (same contract).
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        yield_point();
        f(self.0.get())
    }
}
