//! The exploration scheduler: one baton, real threads, DFS over every
//! sequentially-consistent schedule.
//!
//! A *session* owns the per-run state: one entry per model thread
//! (waiting at a yield point / running / blocked on a join /
//! finished), the baton (`turn`), and the decision trace. Exactly one
//! thread holds the baton at any instant; it runs undisturbed until
//! its next instrumented operation, where it parks and hands control
//! back. The scheduler then promotes joins whose target finished,
//! collects the runnable set, and picks the next thread — by replaying
//! the recorded prefix, or defaulting to the lowest index past it.
//! Every pick is recorded as `(picked, out_of)`; the DFS driver
//! backtracks over that trace.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Marker payload for the internal "session aborted" unwind — used to
/// tear worker threads down after another thread's assertion failed,
/// without mistaking the teardown for a second failure.
struct AbortToken;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TState {
    /// Parked at a yield point, runnable.
    Waiting,
    /// Holds the baton (or has been granted it and will wake).
    Running,
    /// Parked in `JoinHandle::join` until the target finishes.
    Blocked { on: usize },
    Finished,
}

struct SessState {
    threads: Vec<TState>,
    /// The baton: which thread may take its next step.
    turn: Option<usize>,
    /// First assertion failure (panic payload rendered to text).
    panic: Option<String>,
    /// Set when tearing down after a failure: parked threads unwind.
    aborted: bool,
    /// Real join handles, reaped at end of run.
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
}

pub(crate) struct Session {
    m: Mutex<SessState>,
    cv: Condvar,
}

thread_local! {
    /// The ambient session of the current OS thread, if it is a model
    /// thread of an active exploration (`(session, thread index)`).
    static CURRENT: RefCell<Option<(Arc<Session>, usize)>> = const { RefCell::new(None) };
}

/// `true` while the calling thread is a controlled model thread.
pub fn active() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// Instrumented operations call this before executing: outside a
/// model run it is one thread-local read; inside, the thread parks and
/// the scheduler decides who steps next.
pub(crate) fn yield_point() {
    let cur = CURRENT.with(|c| c.borrow().clone());
    if let Some((sess, tid)) = cur {
        sess.pause(tid);
    }
}

impl Session {
    fn new() -> Self {
        Session {
            m: Mutex::new(SessState {
                threads: Vec::new(),
                turn: None,
                panic: None,
                aborted: false,
                handles: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Park at a yield point and wait for the baton.
    fn pause(&self, tid: usize) {
        let mut st = self.m.lock().unwrap();
        st.threads[tid] = TState::Waiting;
        self.cv.notify_all();
        loop {
            if st.aborted {
                drop(st);
                std::panic::panic_any(AbortToken);
            }
            if st.turn == Some(tid) {
                st.turn = None;
                // `Running` was already set by the scheduler at grant
                // time so it never observes a window where nobody is
                // running; just consume the baton.
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// First wait of a freshly spawned thread (registered `Waiting` by
    /// its parent; identical to the tail of [`Session::pause`]).
    fn wait_for_first_grant(&self, tid: usize) {
        let mut st = self.m.lock().unwrap();
        loop {
            if st.aborted {
                drop(st);
                std::panic::panic_any(AbortToken);
            }
            if st.turn == Some(tid) {
                st.turn = None;
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Register a child thread (caller holds the baton). Returns its
    /// index; the matching real join handle lands via [`Session::set_handle`].
    pub(crate) fn register(&self) -> usize {
        let mut st = self.m.lock().unwrap();
        st.threads.push(TState::Waiting);
        st.handles.push(None);
        st.threads.len() - 1
    }

    pub(crate) fn set_handle(&self, tid: usize, h: std::thread::JoinHandle<()>) {
        self.m.lock().unwrap().handles[tid] = Some(h);
    }

    /// Mark `tid` finished (normal return or panic) and wake the
    /// scheduler.
    fn finish(&self, tid: usize, panic_msg: Option<String>) {
        let mut st = self.m.lock().unwrap();
        st.threads[tid] = TState::Finished;
        if let Some(msg) = panic_msg {
            if st.panic.is_none() {
                st.panic = Some(msg);
            }
        }
        self.cv.notify_all();
    }

    /// Block the caller until `target` finishes (join semantics).
    pub(crate) fn join_wait(&self, me: usize, target: usize) {
        let mut st = self.m.lock().unwrap();
        if st.threads[target] == TState::Finished {
            return; // no yield: join of a finished thread is immediate
        }
        st.threads[me] = TState::Blocked { on: target };
        self.cv.notify_all();
        loop {
            if st.aborted {
                drop(st);
                std::panic::panic_any(AbortToken);
            }
            if st.turn == Some(me) {
                st.turn = None;
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

/// The OS-thread body shared by the root closure and spawned threads.
pub(crate) fn run_controlled<F: FnOnce() + std::panic::UnwindSafe>(
    sess: Arc<Session>,
    tid: usize,
    f: F,
) {
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&sess), tid)));
    sess.wait_for_first_grant(tid);
    let result = catch_unwind(f);
    let panic_msg = match result {
        Ok(()) => None,
        Err(e) => {
            if e.downcast_ref::<AbortToken>().is_some() {
                None // teardown unwind, not a failure
            } else if let Some(s) = e.downcast_ref::<&str>() {
                Some((*s).to_string())
            } else if let Some(s) = e.downcast_ref::<String>() {
                Some(s.clone())
            } else {
                Some("model thread panicked (non-string payload)".to_string())
            }
        }
    };
    CURRENT.with(|c| *c.borrow_mut() = None);
    sess.finish(tid, panic_msg);
}

pub(crate) fn current() -> Option<(Arc<Session>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// One scheduler decision: which runnable thread was picked, out of
/// how many options (options are thread indices in ascending order, so
/// `picked` is an index into that deterministic list).
#[derive(Clone, Copy, Debug)]
struct Choice {
    picked: usize,
    options: usize,
}

/// Outcome of one full exploration.
#[derive(Debug, Clone, Copy)]
pub struct Explored {
    /// Schedules actually run.
    pub schedules: usize,
    /// `true` when the DFS exhausted the space (vs hit the run budget).
    pub complete: bool,
}

/// Hard cap on decisions per schedule — a schedule this long means a
/// livelock (or an unbounded loop) in the modeled code.
const MAX_STEPS_PER_RUN: usize = 50_000;

struct RunOutcome {
    choices: Vec<Choice>,
    panic: Option<String>,
}

fn run_once(f: Arc<dyn Fn() + Send + Sync>, prefix: &[usize]) -> RunOutcome {
    let sess = Arc::new(Session::new());
    {
        let mut st = sess.m.lock().unwrap();
        st.threads.push(TState::Waiting); // root = thread 0
        st.handles.push(None);
    }
    let root_sess = Arc::clone(&sess);
    let root = std::thread::spawn(move || {
        let g = AssertUnwindSafe(move || f());
        run_controlled(Arc::clone(&root_sess), 0, g)
    });
    sess.m.lock().unwrap().handles[0] = Some(root);

    let mut choices: Vec<Choice> = Vec::new();
    let panic_msg = loop {
        let mut st = sess.m.lock().unwrap();
        // wait until the granted thread has parked again (or finished)
        st = self::wait_quiescent(&sess, st);
        if st.panic.is_some() {
            break st.panic.clone();
        }
        // promote joins whose target has finished
        for i in 0..st.threads.len() {
            if let TState::Blocked { on } = st.threads[i] {
                if st.threads[on] == TState::Finished {
                    st.threads[i] = TState::Waiting;
                }
            }
        }
        let enabled: Vec<usize> = (0..st.threads.len())
            .filter(|&i| st.threads[i] == TState::Waiting)
            .collect();
        if enabled.is_empty() {
            if st.threads.iter().all(|&t| t == TState::Finished) {
                break None; // schedule fully executed
            }
            break Some("deadlock: every live thread is blocked on a join".to_string());
        }
        if choices.len() >= MAX_STEPS_PER_RUN {
            break Some(format!(
                "schedule exceeded {MAX_STEPS_PER_RUN} decisions — livelock in modeled code?"
            ));
        }
        let pick = prefix.get(choices.len()).copied().unwrap_or(0).min(enabled.len() - 1);
        choices.push(Choice { picked: pick, options: enabled.len() });
        let t = enabled[pick];
        st.threads[t] = TState::Running;
        st.turn = Some(t);
        sess.cv.notify_all();
        drop(st);
    };

    if panic_msg.is_some() {
        // teardown: unpark every surviving thread into an abort unwind
        let mut st = sess.m.lock().unwrap();
        st.aborted = true;
        sess.cv.notify_all();
        drop(st);
    }
    // reap: every thread either finished normally or unwinds on abort
    let handles: Vec<_> = {
        let mut st = sess.m.lock().unwrap();
        st.handles.iter_mut().map(|h| h.take()).collect()
    };
    for h in handles.into_iter().flatten() {
        let _ = h.join(); // panicked model threads already reported
    }
    RunOutcome { choices, panic: panic_msg }
}

fn wait_quiescent<'a>(
    sess: &'a Session,
    guard: std::sync::MutexGuard<'a, SessState>,
) -> std::sync::MutexGuard<'a, SessState> {
    sess.cv
        .wait_while(guard, |s| {
            s.panic.is_none()
                && (s.turn.is_some() || s.threads.iter().any(|&t| t == TState::Running))
        })
        .unwrap()
}

/// Explore every schedule of `f`, or panic with the counterexample
/// trace. Panics if the space exceeds the default budget — split the
/// scenario instead of raising it.
pub fn model<F: Fn() + Send + Sync + 'static>(f: F) -> Explored {
    const DEFAULT_BUDGET: usize = 1_000_000;
    let explored = model_bounded(f, DEFAULT_BUDGET);
    assert!(
        explored.complete,
        "loomsim: schedule space exceeded the {DEFAULT_BUDGET}-run budget — \
         shrink the scenario so the proof stays exhaustive"
    );
    explored
}

/// [`model`] with an explicit run budget; returns whether the DFS
/// completed. A failure still panics with the schedule trace.
pub fn model_bounded<F: Fn() + Send + Sync + 'static>(f: F, max_runs: usize) -> Explored {
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
    let mut prefix: Vec<usize> = Vec::new();
    let mut runs = 0usize;
    loop {
        runs += 1;
        let out = run_once(Arc::clone(&f), &prefix);
        if let Some(msg) = out.panic {
            let trace: Vec<usize> = out.choices.iter().map(|c| c.picked).collect();
            panic!(
                "loomsim: failure under schedule {trace:?} (run {runs}): {msg}\n\
                 (each entry picks the n-th runnable thread at that decision point)"
            );
        }
        // DFS backtrack: deepest decision with an untried alternative
        let mut stack = out.choices;
        while let Some(last) = stack.last() {
            if last.picked + 1 < last.options {
                break;
            }
            stack.pop();
        }
        match stack.last_mut() {
            None => return Explored { schedules: runs, complete: true },
            Some(last) => last.picked += 1,
        }
        prefix = stack.iter().map(|c| c.picked).collect();
        if runs >= max_runs {
            return Explored { schedules: runs, complete: false };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loomsim::atomic::AtomicUsize;
    use crate::loomsim::thread;
    use std::sync::atomic::Ordering;

    #[test]
    fn a_single_thread_has_exactly_one_schedule() {
        let e = model(|| {
            let a = AtomicUsize::new(0);
            a.store(1, Ordering::SeqCst);
            assert_eq!(a.load(Ordering::SeqCst), 1);
        });
        assert_eq!(e.schedules, 1, "no concurrency, no branching");
    }

    // Schedule counts below: a spawned thread's first grant only
    // advances it from "not started" to "parked at its first op" — an
    // *activation* step that interleaves like an op of its own. A child
    // with k instrumented ops therefore contributes k+1 tokens.

    #[test]
    fn two_single_op_threads_explore_both_orders() {
        // root: [store]; child: [activate, store] → C(3,1) = 3
        // schedules, covering both store orders (one is reached twice).
        let e = model(|| {
            let a = std::sync::Arc::new(AtomicUsize::new(0));
            let a2 = std::sync::Arc::clone(&a);
            let t = thread::spawn(move || a2.store(1, Ordering::SeqCst));
            a.store(2, Ordering::SeqCst);
            t.join();
        });
        assert_eq!(e.schedules, 3);
    }

    #[test]
    fn interleaving_count_matches_the_binomial() {
        // root: 2 ops; child: activate + 2 ops → C(5,2) = 10
        let e = model(|| {
            let a = std::sync::Arc::new(AtomicUsize::new(0));
            let a2 = std::sync::Arc::clone(&a);
            let t = thread::spawn(move || {
                a2.fetch_add(1, Ordering::SeqCst);
                a2.fetch_add(1, Ordering::SeqCst);
            });
            a.fetch_add(10, Ordering::SeqCst);
            a.fetch_add(10, Ordering::SeqCst);
            let _ = t.join();
        });
        assert_eq!(e.schedules, 10);
    }

    #[test]
    fn exploration_finds_the_lost_update() {
        // the canonical non-atomic increment: load, then store(x+1).
        // Exhaustive exploration must observe BOTH outcomes: 2 (serial)
        // and 1 (both loads before either store — the lost update).
        use std::sync::Mutex as StdMutex;
        let outcomes = std::sync::Arc::new(StdMutex::new(std::collections::BTreeSet::new()));
        let sink = std::sync::Arc::clone(&outcomes);
        model(move || {
            let a = std::sync::Arc::new(AtomicUsize::new(0));
            let (a1, a2) = (std::sync::Arc::clone(&a), std::sync::Arc::clone(&a));
            let inc = |x: std::sync::Arc<AtomicUsize>| {
                let v = x.load(Ordering::SeqCst);
                x.store(v + 1, Ordering::SeqCst);
            };
            let t1 = thread::spawn(move || inc(a1));
            let t2 = thread::spawn(move || inc(a2));
            t1.join();
            t2.join();
            sink.lock().unwrap().insert(a.load(Ordering::SeqCst));
        });
        let seen = outcomes.lock().unwrap();
        assert!(seen.contains(&1), "must find the lost-update interleaving, saw {seen:?}");
        assert!(seen.contains(&2), "must find the serial interleaving, saw {seen:?}");
    }

    #[test]
    fn cas_makes_the_increment_exact_under_every_schedule() {
        // the fixed version of the test above: a CAS retry loop always
        // ends at 2 — the assertion runs inside every explored schedule
        model(|| {
            let a = std::sync::Arc::new(AtomicUsize::new(0));
            let (a1, a2) = (std::sync::Arc::clone(&a), std::sync::Arc::clone(&a));
            let inc = |x: std::sync::Arc<AtomicUsize>| loop {
                let v = x.load(Ordering::SeqCst);
                if x.compare_exchange(v, v + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok() {
                    break;
                }
            };
            let t1 = thread::spawn(move || inc(a1));
            let t2 = thread::spawn(move || inc(a2));
            t1.join();
            t2.join();
            assert_eq!(a.load(Ordering::SeqCst), 2);
        });
    }

    #[test]
    fn join_returns_the_child_value_and_orders_after_it() {
        model(|| {
            let a = std::sync::Arc::new(AtomicUsize::new(0));
            let a2 = std::sync::Arc::clone(&a);
            let t = thread::spawn(move || {
                a2.store(7, Ordering::SeqCst);
                41
            });
            let v = t.join();
            assert_eq!(v, 41);
            assert_eq!(a.load(Ordering::SeqCst), 7, "join is a happens-before edge");
        });
    }

    #[test]
    #[should_panic(expected = "loomsim: failure under schedule")]
    fn a_failing_assertion_reports_its_schedule() {
        model(|| {
            let a = std::sync::Arc::new(AtomicUsize::new(0));
            let a2 = std::sync::Arc::clone(&a);
            let t = thread::spawn(move || a2.store(1, Ordering::SeqCst));
            let seen = a.load(Ordering::SeqCst);
            t.join();
            // fails on the schedule where the child ran first
            assert_eq!(seen, 0, "deliberate failure for the trace test");
        });
    }

    #[test]
    fn instrumented_atomics_pass_through_outside_a_model() {
        assert!(!active());
        let a = AtomicUsize::new(5);
        assert_eq!(a.fetch_add(2, Ordering::SeqCst), 5);
        assert_eq!(a.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn bounded_exploration_reports_incompleteness_honestly() {
        // root 6 ops vs child activate+6 ops = C(13,6) = 1716
        // schedules; a budget of 10 must come back incomplete (and not
        // panic)
        let e = model_bounded(
            || {
                let a = std::sync::Arc::new(AtomicUsize::new(0));
                let a2 = std::sync::Arc::clone(&a);
                let t = thread::spawn(move || {
                    for _ in 0..6 {
                        a2.fetch_add(1, Ordering::SeqCst);
                    }
                });
                for _ in 0..6 {
                    a.fetch_add(1, Ordering::SeqCst);
                }
                t.join();
            },
            10,
        );
        assert!(!e.complete);
        assert_eq!(e.schedules, 10);
    }
}
