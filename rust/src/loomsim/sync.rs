//! The facade `serve::deque` / `serve::slot` compile against.
//!
//! * **Release builds** (`cfg(not(any(test, loom)))`): plain
//!   `std::sync::atomic` re-exports plus a zero-cost `UnsafeCell`
//!   wrapper with the same access-scoped API — the hot path pays
//!   nothing for being model-checkable.
//! * **`cargo test` and `--cfg loom`**: the instrumented wrappers from
//!   [`super::atomic`] / [`super::cell`], so the interleaving proofs
//!   run inside ordinary unit tests *and* the dedicated loom CI job.
//!
//! Code written against this module must go through `with`/`with_mut`
//! for payload access and use only the atomic-op subset both sides
//! provide.

pub use std::sync::atomic::Ordering;

#[cfg(any(test, loom))]
pub use super::atomic::{
    fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize,
};
#[cfg(any(test, loom))]
pub use super::cell::UnsafeCell;

#[cfg(not(any(test, loom)))]
pub use std::sync::atomic::{
    fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize,
};

#[cfg(not(any(test, loom)))]
mod plain_cell {
    /// Zero-cost stand-in for the instrumented cell: identical API,
    /// compiles down to raw `UnsafeCell` accesses.
    #[derive(Debug, Default)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        pub const fn new(v: T) -> Self {
            Self(std::cell::UnsafeCell::new(v))
        }

        pub fn into_inner(self) -> T {
            self.0.into_inner()
        }

        #[inline(always)]
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get() as *const T)
        }

        #[inline(always)]
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}

#[cfg(not(any(test, loom)))]
pub use plain_cell::UnsafeCell;
