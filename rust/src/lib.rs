//! # HyCA — A Hybrid Computing Architecture for Fault-Tolerant Deep Learning
//!
//! Full reproduction of Liu et al., *"HyCA: A Hybrid Computing
//! Architecture for Fault Tolerant Deep Learning"* (TCAD 2021,
//! extending ICCD'20), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the DLA simulator, fault models, redundancy
//!   schemes (RR/CR/DR/HyCA), the HyCA micro-architecture (DPPU,
//!   register files, FPT/AGU, runtime fault detection), the Scale-sim
//!   analogue performance model, the area model and the experiment
//!   coordinator that regenerates every figure and table of the paper.
//! * **L2 (python/compile/model.py, build-time)** — the quantized CNN
//!   forward pass with output-feature fault corruption and DPPU
//!   recompute, lowered once to HLO text.
//! * **L1 (python/compile/kernels/, build-time)** — the Pallas
//!   output-stationary matmul kernel with stuck-at corruption, checked
//!   against a pure-jnp oracle.
//!
//! At experiment time only the rust binary runs. Inference executes on
//! a pluggable [`runtime::Backend`]: the hermetic bit-exact
//! [`runtime::native`] interpreter by default, or the compiled HLO
//! artifacts through the PJRT C API under `--features pjrt`
//! (DESIGN.md §3). The default build needs no artifacts, no network and
//! no native libraries.
//!
//! Beyond the paper's artefacts, [`serve`] runs the engine as a
//! long-lived fault-tolerant service — dynamic batching, a
//! multi-threaded worker pool, and online scan-and-repair under live
//! traffic (`repro serve`, DESIGN.md §5) — and [`fleet`] scales that
//! to a multi-chip cluster: sharded serving across independently
//! failing chips behind a health-aware router with drain/re-admit
//! fault-domain isolation (`repro fleet`, DESIGN.md §6). Every
//! serve/fleet experiment is configured through [`scenario`] — a
//! declarative, validated spec API with a canonical `.scn` text
//! format, preset registry and data-driven sweep grids
//! (`repro scenario`, DESIGN.md §7). The real-compute hot path is the
//! work-stealing executor ([`serve::executor`]: per-worker deques,
//! per-chip affinity, zero-copy image access, transposed-mask
//! caching), measured wall-clock by `repro perf` (DESIGN.md §8).
//! The fleet loop itself runs on [`engine`] — an event-sourced
//! command/event-log core with snapshot/restore and time-travel
//! branching (`repro replay`, DESIGN.md §12).
//!
//! Start at [`coordinator`] for the experiment registry, or run
//! `cargo run --release -- list`.

pub mod area;
pub mod array;
pub mod benchkit;
pub mod coordinator;
pub mod engine;
pub mod faults;
pub mod fleet;
pub mod hyca;
pub mod inference;
pub mod loomsim;
pub mod obs;
pub mod perfmodel;
pub mod redundancy;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod testkit;
pub mod util;
