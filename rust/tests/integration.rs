//! Cross-module integration tests: experiments reproduce the paper's
//! qualitative claims end to end (no PJRT — see runtime_e2e.rs for the
//! compiled-model path).

use hyca::area::{dla_area, AreaConstants, AreaScheme};
use hyca::array::Dims;
use hyca::coordinator::{find, registry, RunOpts};
use hyca::faults::montecarlo::FaultModel;
use hyca::hyca::detect::{layers_covering_scan, scan_cycles};
use hyca::hyca::dppu::DppuConfig;
use hyca::perfmodel::networks;
use hyca::redundancy::{
    cr::ColumnRedundancy, dr::DiagonalRedundancy, evaluate_scheme, hyca::HycaScheme,
    rr::RowRedundancy,
};

fn fast_opts() -> RunOpts {
    RunOpts {
        configs: 400,
        fast: true,
        out_dir: std::env::temp_dir().join("hyca_it_results"),
        // hermetic regardless of local artifact state
        builtin_model: true,
        ..RunOpts::default()
    }
}

/// Paper claim (Fig. 10a): HyCA32 keeps FFP ≈ 1 below the 3.13% cliff
/// while RR/CR are near zero by 2% PER under the random model.
#[test]
fn hyca_ffp_cliff_at_dppu_capacity() {
    let dims = Dims::PAPER;
    let n = 600;
    let args = |per| (dims, per, FaultModel::Random, 42u64, n, 2usize);
    let hyca = HycaScheme::paper(32);
    let (ffp_low, _) = {
        let a = args(0.02);
        evaluate_scheme(&hyca, a.0, a.1, a.2, a.3, a.4, a.5)
    };
    assert!(ffp_low > 0.95, "HyCA at 2% PER: {ffp_low}");
    let (ffp_high, _) = {
        let a = args(0.05);
        evaluate_scheme(&hyca, a.0, a.1, a.2, a.3, a.4, a.5)
    };
    assert!(ffp_high < 0.05, "HyCA past the cliff at 5% PER: {ffp_high}");
    let (rr, _) = {
        let a = args(0.02);
        evaluate_scheme(&RowRedundancy::default(), a.0, a.1, a.2, a.3, a.4, a.5)
    };
    assert!(rr < 0.1, "RR at 2% PER should be nearly dead: {rr}");
}

/// Paper claim (Fig. 10b): the classical schemes lose FFP under
/// clustering while HyCA only cares about the fault count.
#[test]
fn clustering_hurts_classical_more_than_hyca() {
    let dims = Dims::PAPER;
    let per = 0.01;
    let n = 800;
    let eval = |s: &dyn hyca::redundancy::Scheme, m| {
        evaluate_scheme(s, dims, per, m, 7, n, 2).0
    };
    let dr_rand = eval(&DiagonalRedundancy, FaultModel::Random);
    let dr_clus = eval(&DiagonalRedundancy, FaultModel::both()[1]);
    assert!(
        dr_clus < dr_rand - 0.1,
        "DR should suffer under clustering: {dr_rand} vs {dr_clus}"
    );
    let hy_rand = eval(&HycaScheme::paper(32), FaultModel::Random);
    let hy_clus = eval(&HycaScheme::paper(32), FaultModel::both()[1]);
    assert!(hy_rand > 0.99, "{hy_rand}");
    // HyCA's clustered FFP only drops via count over-dispersion, much
    // less than DR's structural failure:
    assert!(
        hy_clus > dr_clus + 0.1,
        "HyCA clustered {hy_clus} vs DR clustered {dr_clus}"
    );
}

/// Paper claim (§V-D): ~25× computing-power advantage of HyCA over RR
/// at 6% PER, random model (we accept ≥ 10× to stay robust to the
/// clamped Monte-Carlo size).
#[test]
fn computing_power_gap_at_high_per() {
    let dims = Dims::PAPER;
    let n = 600;
    let (_, p_rr) = evaluate_scheme(
        &RowRedundancy::default(), dims, 0.06, FaultModel::Random, 11, n, 2,
    );
    let (_, p_hyca) = evaluate_scheme(
        &HycaScheme::paper(32), dims, 0.06, FaultModel::Random, 11, n, 2,
    );
    let ratio = p_hyca / p_rr.max(1e-6);
    assert!(
        ratio > 10.0,
        "HyCA/RR computing power at 6%: {ratio:.1} (hyca {p_hyca:.3}, rr {p_rr:.3})"
    );
}

/// Paper claim (Fig. 9): every HyCA size costs less than every
/// classical scheme's overhead.
#[test]
fn area_ranking_matches_fig9() {
    let c = AreaConstants::default();
    let over = |s| dla_area(&c, Dims::PAPER, s).overhead_kge();
    let classical = [over(AreaScheme::Rr), over(AreaScheme::Cr), over(AreaScheme::Dr)];
    for size in [24, 32, 40] {
        let h = over(AreaScheme::Hyca(DppuConfig::paper(size)));
        for cl in classical {
            assert!(h < cl);
        }
    }
}

/// Paper Table I: every network's layers cover the scan up to 64×64
/// (our analytic runtime leaves ResNet's smallest 1×1 projection just
/// under the threshold at 64×64 — a documented borderline, see
/// EXPERIMENTS.md); at 128×128 AlexNet/YOLO/ResNet lose coverage but
/// VGG keeps 16/16.
#[test]
fn detection_coverage_matches_table1_pattern() {
    for dims in [Dims::new(16, 16), Dims::new(32, 32)] {
        for net in networks::benchmark() {
            let cov = layers_covering_scan(dims, &net.layer_cycles(dims).unwrap());
            assert_eq!(cov, net.layers.len(), "{} on {dims}", net.name);
        }
    }
    let mid = Dims::new(64, 64);
    for net in networks::benchmark() {
        let cov = layers_covering_scan(mid, &net.layer_cycles(mid).unwrap());
        assert!(
            cov + 1 >= net.layers.len(),
            "{} on {mid}: {cov}/{}",
            net.name,
            net.layers.len()
        );
    }
    let big = Dims::new(128, 128);
    let cov = |name: &str| {
        let net = networks::benchmark()
            .into_iter()
            .find(|n| n.name == name)
            .unwrap();
        (
            layers_covering_scan(big, &net.layer_cycles(big).unwrap()),
            net.layers.len(),
        )
    };
    let (vgg, vgg_total) = cov("VGG");
    assert_eq!(vgg, vgg_total, "VGG keeps full coverage at 128x128");
    let (alex, alex_total) = cov("Alexnet");
    assert!(alex < alex_total, "AlexNet loses coverage at 128x128");
    let (res, res_total) = cov("Resnet");
    assert!(res < res_total, "ResNet loses coverage at 128x128");
    // scan time itself matches the formula
    assert_eq!(scan_cycles(big), 128 * 128 + 128);
}

/// Fig. 15 pattern: grouped scales with size; unified plateaus at the
/// alignment boundary (capacity(24) == capacity(16), capacity(48) ==
/// capacity(32)).
#[test]
fn dppu_structure_scalability_pattern() {
    let dims = Dims::PAPER;
    let per = 0.022; // ~22 expected faults: between 16 and 32 capacity
    let n = 600;
    let ffp = |scheme: HycaScheme| {
        evaluate_scheme(&scheme, dims, per, FaultModel::Random, 3, n, 2).0
    };
    let g24 = ffp(HycaScheme { model_dppu_faults: false, ..HycaScheme::paper(24) });
    let u24 = ffp(HycaScheme { model_dppu_faults: false, ..HycaScheme::unified(24) });
    let u16 = ffp(HycaScheme { model_dppu_faults: false, ..HycaScheme::unified(16) });
    assert!(g24 > u24 + 0.2, "grouped 24 ({g24}) ≫ unified 24 ({u24})");
    assert!((u24 - u16).abs() < 0.05, "unified 24 ≈ unified 16");
}

/// Every registered experiment runs to completion on a fast sweep and
/// produces at least one non-empty table. fig2 included: it runs on the
/// builtin model through the native backend when no artifacts exist.
#[test]
fn all_simulation_experiments_run() {
    let opts = fast_opts();
    for e in registry() {
        let tables = e.run(&opts).unwrap_or_else(|err| panic!("{}: {err}", e.id()));
        assert!(!tables.is_empty(), "{}", e.id());
        for t in &tables {
            assert!(!t.rows.is_empty(), "{} empty table", e.id());
        }
    }
    std::fs::remove_dir_all(&opts.out_dir).ok();
}

/// Registry lookup used by the CLI.
#[test]
fn cli_registry_contract() {
    assert!(find("table1").is_some());
    assert!(find("serve").is_some());
    assert!(find("fleet").is_some());
    assert_eq!(registry().len(), 12);
}
