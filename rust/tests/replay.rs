//! Event-sourcing acceptance tests (`repro replay`, DESIGN.md §12).
//!
//! 1. **Resume identity**: `resume(snapshot, log_tail)` is
//!    byte-identical to the uninterrupted run — from *every* snapshot
//!    boundary, on an open-loop diurnal scenario and on a closed-loop
//!    fault/drain scenario (mask epochs included).
//! 2. **Golden determinism**: `BENCH_replay.json` is a pure function
//!    of the master seed — byte-identical at any `--workers` value.
//! 3. **Branch identity**: a fork-free branch reproduces the base run
//!    bit-for-bit; a fault-override branch shares the pre-fork prefix
//!    and its span-ledger divergence lands at or after the fork.
//! 4. **Integrity**: the snapshot byte format round-trips, and the
//!    FNV-1a integrity hash rejects corruption; the event-log codec
//!    round-trips and truncation recovers the longest valid prefix.

use hyca::coordinator::{exp_replay, RunOpts};
use hyca::engine::{
    decode_log, encode_log, BranchOverrides, ClusterEngine, Snapshot, SnapshotError,
};
use hyca::inference::Engine;
use hyca::obs::{recorder, FlightRecorder, NullSink, Probe};

const SEED: u64 = 0xC0FFEE;

fn opts(seed: u64, threads: usize) -> RunOpts {
    RunOpts {
        seed,
        threads,
        out_dir: std::env::temp_dir().join("hyca_replay_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

#[test]
fn resume_from_every_snapshot_is_byte_identical() {
    let engine = Engine::builtin();
    // one open-loop scenario with autoscaling (the canonical replay
    // preset, smoke horizon) and one closed-loop scenario with fault
    // episodes + drain/re-admit, so resumed mask epochs are exercised
    for (preset, every) in [("long_diurnal", 0u64), ("degraded_continuity", 10_000)] {
        let spec = exp_replay::replay_spec(preset).unwrap();
        let cadence = if every == 0 { exp_replay::snapshot_cadence(&spec, true) } else { every };
        let cfg = exp_replay::replay_config(&spec, SEED, true, 1);
        let base = exp_replay::run_base(&engine, &cfg, cadence);
        assert!(
            base.snaps.len() >= 2,
            "{preset}: need several snapshot boundaries, got {}",
            base.snaps.len()
        );
        for snap in &base.snaps {
            // hard-fails unless the replayed tail equals the
            // uninterrupted log tail and the digests match
            exp_replay::resume_and_verify(&engine, &cfg, snap, &base)
                .unwrap_or_else(|e| panic!("{preset}: {e}"));
        }
    }
}

#[test]
fn resumed_timeline_matches_piecewise_including_masks() {
    let engine = Engine::builtin();
    let spec = exp_replay::replay_spec("degraded_continuity").unwrap();
    let cfg = exp_replay::replay_config(&spec, SEED, true, 1);
    let base = exp_replay::run_base(&engine, &cfg, 10_000);
    let snap = &base.snaps[base.snaps.len() / 2];
    let mut core = ClusterEngine::resume(&engine, &cfg, snap).unwrap();
    let mut rec = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
    let mut sink = NullSink;
    let mut probe = Probe { sink: &mut sink, rec: &mut rec };
    core.run(&mut probe);
    let resumed = core.finish(&mut probe);
    assert_eq!(resumed.requests, base.timeline.requests, "request records diverged");
    assert_eq!(resumed.total_cycles, base.timeline.total_cycles);
    assert_eq!(resumed.events, base.timeline.events, "cluster events diverged");
    assert_eq!(resumed.shed_cycles, base.timeline.shed_cycles);
    assert_eq!(resumed.max_pending, base.timeline.max_pending);
    assert_eq!(resumed.jobs.len(), base.timeline.jobs.len());
    for (r, b) in resumed.jobs.iter().zip(&base.timeline.jobs) {
        assert_eq!(r.chip, b.chip);
        assert_eq!(r.job.id, b.job.id);
        assert_eq!(r.job.image_idxs, b.job.image_idxs);
        assert_eq!((r.job.start_cycle, r.job.end_cycle), (b.job.start_cycle, b.job.end_cycle));
        assert_eq!(r.job.lane, b.job.lane);
        // the load-bearing part of resume: mask epochs are static
        // context recomputed from the config, and must match the
        // epochs the uninterrupted run dispatched with
        assert_eq!(*r.job.masks, *b.job.masks, "mask epochs diverged on job {}", b.job.id);
    }
}

#[test]
fn bench_json_is_byte_identical_at_any_worker_count() {
    let narrow = exp_replay::bench_json_only(&opts(SEED, 1), true).unwrap();
    let wide = exp_replay::bench_json_only(&opts(SEED, 8), true).unwrap();
    assert_eq!(narrow, wide, "worker count leaked into the replay bench");
    let again = exp_replay::bench_json_only(&opts(SEED, 1), true).unwrap();
    assert_eq!(narrow, again);
    let other = exp_replay::bench_json_only(&opts(0xBEEF, 1), true).unwrap();
    assert_ne!(narrow, other, "the seed must reach the event stream");
    for key in [
        "\"schema\": \"hyca-replay-bench-v1\"",
        "\"scenario\": \"long_diurnal\"",
        "\"spec_hash\":",
        "\"snapshot_every_cycles\":",
        "\"total_cycles\":",
        "\"offered\":",
        "\"admitted\":",
        "\"shed\":",
        "\"batches\":",
        "\"log_events\":",
        "\"digest\":",
    ] {
        assert!(narrow.contains(key), "missing {key} in:\n{narrow}");
    }
    for forbidden in ["seconds", "wall", "ns_per"] {
        assert!(!narrow.contains(forbidden), "wall-clock field {forbidden:?}");
    }
}

#[test]
fn branches_fork_free_identity_and_fault_override_diverges_after_fork() {
    let engine = Engine::builtin();
    let spec = exp_replay::replay_spec(exp_replay::DEFAULT_PRESET).unwrap();
    let cfg = exp_replay::replay_config(&spec, SEED, true, 1);
    let every = exp_replay::snapshot_cadence(&spec, true);
    let base = exp_replay::run_base(&engine, &cfg, every);
    assert!(base.snaps.len() >= 3, "need an early fork with post-fork traffic");
    let fork = base.snaps[1].label_cycle;

    // fork-free: run_branch itself asserts bit-identity before
    // returning; the ledger must agree nothing diverged
    let id = exp_replay::run_branch(&engine, &cfg, &base, &BranchOverrides::default(), Some(fork))
        .unwrap();
    assert!(id.divergence.is_none());
    assert_eq!(id.digest, base.digest);
    assert_eq!(id.events.len(), base.log.len());

    // counterfactual: chip 0 forced drained at the fork
    let ov = BranchOverrides {
        fork_cycle: Some(fork),
        kill_chip: Some((0, fork)),
        rate_scale: None,
    };
    let b = exp_replay::run_branch(&engine, &cfg, &base, &ov, None).unwrap();
    assert_ne!(b.digest, base.digest, "killing a chip must change the timeline");
    // the shared prefix really is shared: every event logged before
    // the fork snapshot is bit-identical
    let off = base
        .snaps
        .iter()
        .rev()
        .find(|s| s.label_cycle <= fork)
        .unwrap()
        .events_logged as usize;
    assert_eq!(&b.events[..off], &base.log[..off], "pre-fork history must be untouched");
    // and the observable onset of the counterfactual is at/after the
    // fork cycle in the span ledger
    let d = b.divergence.expect("the span ledgers must disagree somewhere");
    assert!(d >= fork, "divergence at cycle {d} precedes the fork at {fork}");
}

#[test]
fn snapshot_bytes_round_trip_and_corruption_is_rejected() {
    let engine = Engine::builtin();
    let spec = exp_replay::replay_spec(exp_replay::DEFAULT_PRESET).unwrap();
    let cfg = exp_replay::replay_config(&spec, SEED, true, 1);
    let every = exp_replay::snapshot_cadence(&spec, true);
    let base = exp_replay::run_base(&engine, &cfg, every);
    let snap = base.snaps.last().unwrap();
    let bytes = snap.to_bytes();
    let back = Snapshot::from_bytes(&bytes).unwrap();
    assert_eq!(&back, snap, "byte round-trip changed the snapshot");
    // flip one bit in a spread of positions: the integrity hash (or
    // the magic/version check) must reject every one
    let step = (bytes.len() * 8 / 64).max(1);
    for bit in (0..bytes.len() * 8).step_by(step) {
        let mut bad = bytes.clone();
        bad[bit / 8] ^= 1 << (bit % 8);
        assert!(
            Snapshot::from_bytes(&bad).is_err(),
            "single-bit flip at bit {bit} went undetected"
        );
    }
    // truncation is its own error, not a panic
    assert!(matches!(
        Snapshot::from_bytes(&bytes[..bytes.len() / 2]),
        Err(SnapshotError::BadHash | SnapshotError::Truncated)
    ));
}

#[test]
fn event_log_codec_round_trips_and_truncation_keeps_the_valid_prefix() {
    let engine = Engine::builtin();
    let spec = exp_replay::replay_spec(exp_replay::DEFAULT_PRESET).unwrap();
    let cfg = exp_replay::replay_config(&spec, SEED, true, 1);
    let every = exp_replay::snapshot_cadence(&spec, true);
    let base = exp_replay::run_base(&engine, &cfg, every);
    assert!(!base.log.is_empty());
    let bytes = encode_log(&base.log);
    let (decoded, truncated) = decode_log(&bytes);
    assert!(!truncated);
    assert_eq!(decoded, base.log, "codec round-trip changed the log");
    // chop mid-frame: the decoder keeps the longest valid prefix and
    // reports the truncation (the crash-restart path relies on both)
    let (partial, cut) = decode_log(&bytes[..bytes.len() / 2]);
    assert!(cut, "a mid-frame cut must be reported");
    assert!(partial.len() < base.log.len());
    assert_eq!(&partial[..], &base.log[..partial.len()], "surviving prefix must be intact");
}

#[test]
fn crash_restart_from_run_dir_produces_the_uninterrupted_bench() {
    // the CI smoke in miniature, in-process: fresh run persists
    // artifacts, the log is truncated mid-frame, the restart resumes
    // from the last usable snapshot and the bench bytes come out
    // identical to the uninterrupted run's
    let dir = std::env::temp_dir().join(format!("hyca_replay_restart_{SEED:x}"));
    let _ = std::fs::remove_dir_all(&dir);
    let o = opts(SEED, 2);
    let (_t, fresh) = exp_replay::run_cli(
        &o,
        true,
        exp_replay::DEFAULT_PRESET,
        None,
        None,
        Some(dir.to_str().unwrap()),
    )
    .unwrap();
    let log_path = dir.join("events.log");
    let bytes = std::fs::read(&log_path).unwrap();
    std::fs::write(&log_path, &bytes[..bytes.len() / 2]).unwrap();
    let (_t2, restarted) = exp_replay::run_cli(
        &o,
        true,
        exp_replay::DEFAULT_PRESET,
        None,
        None,
        Some(dir.to_str().unwrap()),
    )
    .unwrap();
    assert_eq!(fresh, restarted, "crash-restart bench must be byte-identical");
    // the restart healed the log: a full decode succeeds untruncated
    let (healed, truncated) = decode_log(&std::fs::read(&log_path).unwrap());
    assert!(!truncated, "healed log must decode cleanly");
    assert!(!healed.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}
