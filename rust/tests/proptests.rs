//! Property-based tests on the coordinator's invariants (testkit::prop
//! — the in-repo proptest substitute, see DESIGN.md §2.1).
//!
//! These pin the algebraic properties the paper's claims rest on:
//! repair-scheme dominance, left-first optimality, capacity formulas,
//! schedule safety and mapping consistency.

use hyca::array::sim::{ConvLayer, FcLayer};
use hyca::array::{mapping, Dims};
use hyca::faults::montecarlo::FaultModel;
use hyca::faults::stuckat::sample_stuck_mask;
use hyca::faults::{random, FaultConfig};
use hyca::hyca::dppu::DppuConfig;
use hyca::hyca::schedule::{build_schedule, simulate_window_drain};
use hyca::inference::masks::{LayerMasks, MaskPair};
use hyca::inference::{oracle_logits, ModelParams};
use hyca::redundancy::{
    cr::ColumnRedundancy, dr::DiagonalRedundancy, hyca::HycaScheme, rr::RowRedundancy,
    RepairCtx, RepairOutcome, Scheme,
};
use hyca::runtime::{Backend, I32Tensor, NativeBackend};
use hyca::testkit::{check, Gen};
use hyca::util::rng::Pcg32;

fn random_dims(g: &mut Gen) -> Dims {
    Dims::new(g.usize_in(2, 48), g.usize_in(2, 48))
}

fn random_cfg(g: &mut Gen, dims: Dims, max_frac: f64) -> FaultConfig {
    let hi = ((dims.len() as f64 * max_frac) as usize).max(1);
    let k = g.usize_in(0, hi.min(dims.len()));
    random::sample_exact(g.rng(), dims, k)
}

fn repair(s: &dyn Scheme, cfg: &FaultConfig, g: &mut Gen) -> RepairOutcome {
    let mut rng = Pcg32::split(0xABCD, g.usize_in(0, 1 << 20) as u64);
    let mut ctx = RepairCtx { per: 0.0, rng: &mut rng };
    s.repair(cfg, &mut ctx)
}

#[test]
fn prop_outcome_bounds() {
    // surviving prefix is always within [0, cols]; fully functional ⇔
    // full prefix survives under every scheme.
    check("outcome bounds", 300, |g| {
        let dims = random_dims(g);
        let cfg = random_cfg(g, dims, 0.2);
        let schemes: Vec<Box<dyn Scheme>> = vec![
            Box::new(RowRedundancy::default()),
            Box::new(ColumnRedundancy::default()),
            Box::new(DiagonalRedundancy),
            Box::new(HycaScheme::ideal(g.usize_in(0, 64))),
        ];
        for s in &schemes {
            let o = repair(s.as_ref(), &cfg, g);
            assert!(o.surviving_cols <= dims.cols, "{}", s.name());
            assert_eq!(o.total_cols, dims.cols);
            if o.fully_functional {
                assert_eq!(o.surviving_cols, dims.cols, "{}", s.name());
            }
        }
    });
}

#[test]
fn prop_surviving_prefix_is_actually_repairable() {
    // For each scheme, the surviving prefix must itself be fully
    // repairable: re-running repair on the faults restricted to the
    // prefix yields fully-functional.
    check("prefix self-consistency", 300, |g| {
        let dims = random_dims(g);
        let cfg = random_cfg(g, dims, 0.3);
        let schemes: Vec<Box<dyn Scheme>> = vec![
            Box::new(RowRedundancy::default()),
            Box::new(ColumnRedundancy::default()),
            Box::new(DiagonalRedundancy),
        ];
        for s in &schemes {
            let o = repair(s.as_ref(), &cfg, g);
            if o.surviving_cols == 0 {
                continue;
            }
            // restrict the fault set to the surviving prefix but keep
            // the *physical* array (the spare structure is unchanged by
            // degradation): the restricted set must be fully repairable.
            let sub = FaultConfig::new(
                dims,
                cfg.faulty()
                    .iter()
                    .filter(|c| (c.col as usize) < o.surviving_cols)
                    .copied()
                    .collect(),
            );
            let o2 = repair(s.as_ref(), &sub, g);
            assert!(
                o2.fully_functional,
                "{}: prefix {} not self-repairable",
                s.name(),
                o.surviving_cols
            );
        }
        // HyCA's capacity is evaluated at the *original* column count
        // (the register-file window is sized by the physical array, not
        // the surviving prefix), so its self-consistency criterion is
        // count-based:
        let hyca = HycaScheme::ideal(g.usize_in(0, 48));
        let o = repair(&hyca, &cfg, g);
        let in_prefix = cfg
            .faulty()
            .iter()
            .filter(|c| (c.col as usize) < o.surviving_cols)
            .count();
        assert!(
            in_prefix <= hyca.dppu.capacity(dims.cols),
            "HyCA prefix holds more faults than capacity"
        );
    });
}

#[test]
fn prop_hyca_dominates_classical_schemes() {
    // With spares = Col (the paper's sizing), ideal HyCA's surviving
    // prefix is ≥ every classical scheme's on every configuration:
    // arbitrary-location repair subsumes constrained repair.
    // (n is a multiple of the DPPU group width 8: otherwise the grouped
    // register-file alignment caps capacity below Col — exactly the
    // Fig. 15 misalignment effect — and dominance is not claimed.)
    check("hyca dominance", 300, |g| {
        let n = 8 * g.usize_in(1, 5);
        let dims = Dims::new(n, n);
        let cfg = random_cfg(g, dims, 0.25);
        let hyca = repair(&HycaScheme::ideal(dims.cols), &cfg, g);
        for s in [
            &RowRedundancy::default() as &dyn Scheme,
            &ColumnRedundancy::default(),
            &DiagonalRedundancy,
        ] {
            let o = repair(s, &cfg, g);
            assert!(
                hyca.surviving_cols >= o.surviving_cols,
                "HyCA {} < {} {}",
                hyca.surviving_cols,
                s.name(),
                o.surviving_cols
            );
        }
    });
}

#[test]
fn prop_hyca_ffp_iff_count_within_capacity() {
    check("hyca capacity criterion", 400, |g| {
        let dims = Dims::new(32, 32);
        let cap = g.usize_in(0, 64);
        let cfg = random_cfg(g, dims, 0.08);
        let scheme = HycaScheme::ideal(cap);
        let o = repair(&scheme, &cfg, g);
        let capacity = scheme.dppu.capacity(dims.cols);
        assert_eq!(o.fully_functional, cfg.count() <= capacity);
    });
}

#[test]
fn prop_hyca_left_first_is_optimal() {
    // No repair subset of size ≤ capacity yields a longer prefix than
    // the left-first choice: the prefix is bounded by the (cap+1)-th
    // fault's column no matter which faults are repaired.
    check("left-first optimality", 300, |g| {
        let dims = Dims::new(16, 32);
        let cfg = random_cfg(g, dims, 0.15);
        let cap = g.usize_in(0, 12);
        let scheme = HycaScheme::ideal(cap);
        let capacity = scheme.dppu.capacity(dims.cols);
        let o = repair(&scheme, &cfg, g);
        if cfg.count() <= capacity {
            assert!(o.fully_functional);
            return;
        }
        // any strategy leaves ≥ count-capacity faults unrepaired; the
        // best achievable prefix is the column of the (capacity+1)-th
        // fault in column order (faults() is column-sorted).
        let bound = cfg.faulty()[capacity].col as usize;
        assert_eq!(o.surviving_cols, bound);
    });
}

#[test]
fn prop_more_spares_never_hurt() {
    // Monotonicity: HyCA with a larger DPPU never yields a shorter
    // prefix; RR/CR with more spares per region likewise.
    check("spare monotonicity", 300, |g| {
        let dims = random_dims(g);
        let cfg = random_cfg(g, dims, 0.2);
        let a = g.usize_in(0, 32);
        let b = a + g.usize_in(0, 32);
        let oa = repair(&HycaScheme::ideal(a), &cfg, g);
        let ob = repair(&HycaScheme::ideal(b), &cfg, g);
        assert!(ob.surviving_cols >= oa.surviving_cols);
        let r1 = repair(&RowRedundancy { spares_per_row: 1, ..Default::default() }, &cfg, g);
        let r2 = repair(&RowRedundancy { spares_per_row: 2, ..Default::default() }, &cfg, g);
        // and the per-PE-spare variant dominates all-or-nothing
        let rp = repair(&RowRedundancy::per_pe_spare(), &cfg, g);
        assert!(rp.surviving_cols >= r1.surviving_cols);
        assert!(r2.surviving_cols >= r1.surviving_cols);
        let c1 = repair(&ColumnRedundancy { spares_per_col: 1 }, &cfg, g);
        let c2 = repair(&ColumnRedundancy { spares_per_col: 2 }, &cfg, g);
        assert!(c2.surviving_cols >= c1.surviving_cols);
    });
}

#[test]
fn prop_fewer_faults_never_hurt() {
    // Removing a fault never shrinks any scheme's surviving prefix.
    check("fault monotonicity", 200, |g| {
        let dims = Dims::new(g.usize_in(4, 24), g.usize_in(4, 24));
        let cfg = random_cfg(g, dims, 0.25);
        if cfg.count() == 0 {
            return;
        }
        let drop = g.usize_in(0, cfg.count() - 1);
        let reduced = FaultConfig::new(
            dims,
            cfg.faulty()
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, c)| *c)
                .collect(),
        );
        for s in [
            &RowRedundancy::default() as &dyn Scheme,
            &ColumnRedundancy::default(),
            &DiagonalRedundancy,
            &HycaScheme::ideal(8),
        ] {
            let full = repair(s, &cfg, g);
            let red = repair(s, &reduced, g);
            assert!(
                red.surviving_cols >= full.surviving_cols,
                "{}: removing a fault shrank prefix",
                s.name()
            );
        }
    });
}

#[test]
fn prop_window_sim_matches_capacity_formula() {
    // The event-level window simulation and the closed-form capacity
    // agree for every structure/size/col combination.
    check("window sim == capacity", 400, |g| {
        let col = *g.choose(&[8usize, 16, 32, 64]);
        let group = *g.choose(&[4usize, 8, 16]);
        let size = group * g.usize_in(1, 8);
        let cfgs = [
            DppuConfig {
                size,
                structure: hyca::hyca::dppu::DppuStructure::Grouped { group_size: group },
                mult_ring: 4,
                add_ring: 3,
            },
            DppuConfig::unified(size),
        ];
        for d in cfgs {
            let cap = d.capacity(col);
            let offered = g.usize_in(0, 2 * cap + 8);
            let drained = simulate_window_drain(&d, col, offered);
            assert_eq!(drained, offered.min(cap), "{d:?} col={col}");
        }
    });
}

#[test]
fn prop_schedule_safety() {
    // build_schedule accepts exactly the configurations whose phases
    // fit: D + F ≤ T_iter and F ≤ capacity; accepted schedules are
    // internally consistent.
    check("schedule safety", 400, |g| {
        let col = *g.choose(&[16usize, 32]);
        let dppu = DppuConfig::paper(*g.choose(&[16usize, 32, 48]));
        let t_iter = g.usize_in(col / 2, 4096);
        let faults = g.usize_in(0, 64);
        match build_schedule(&dppu, t_iter, col, faults) {
            Ok(ph) => {
                assert!(faults <= dppu.capacity(col));
                assert!(col + faults <= t_iter);
                assert_eq!(ph.array_write_end, col);
                assert_eq!(ph.dppu_write_end, col + faults);
                assert_eq!(ph.t_iter, t_iter);
                assert_eq!(ph.idle_cycles(), t_iter - col - faults);
            }
            Err(_) => {
                assert!(faults > dppu.capacity(col) || col + faults > t_iter);
            }
        }
    });
}

#[test]
fn prop_mapping_partition() {
    // Every output feature of a layer maps to exactly one PE, and the
    // per-PE output lists partition the corrupted-output map.
    check("mapping partition", 200, |g| {
        let dims = Dims::new(g.usize_in(2, 16), g.usize_in(2, 16));
        let out = mapping::LayerOutput::Conv {
            oc: g.usize_in(1, 24),
            oh: g.usize_in(1, 12),
            ow: g.usize_in(1, 12),
        };
        let cfg = random_cfg(g, dims, 1.0); // any subset of PEs
        let map = mapping::corrupted_outputs(&cfg, out);
        let mut covered = vec![false; out.len()];
        for (_, _, outs) in mapping::outputs_of_faulty_pes(&cfg, out) {
            for o in outs {
                assert!(!covered[o], "output {o} claimed twice");
                covered[o] = true;
            }
        }
        assert_eq!(covered, map, "per-PE lists must equal the corruption map");
    });
}

fn random_conv(g: &mut Gen, in_c: usize, out_c: usize) -> ConvLayer {
    let k = *g.choose(&[1usize, 3]);
    ConvLayer {
        out_c,
        in_c,
        k,
        stride: 1,
        pad: k / 2, // keeps the spatial size, so the pool halvings line up
        weights: (0..out_c * in_c * k * k)
            .map(|_| (g.rng().below(7) as i32 - 3) as i8)
            .collect(),
        bias: (0..out_c).map(|_| g.rng().below(65) as i32 - 32).collect(),
        m: g.usize_in(1, 3) as i32,
        shift: g.usize_in(2, 8) as u32,
        relu: g.bool(0.7),
    }
}

#[test]
fn prop_native_backend_matches_sim_oracle() {
    // The paper's bit-exactness contract (rust/src/array/sim.rs header):
    // for random small ConvLayer/FcLayer shapes and random StuckMask
    // sets, the native backend's logits equal `oracle_logits`
    // bit-for-bit. The two implementations are deliberately independent
    // (the backend goes through sim::corrupt_acc, the oracle masks
    // inline), so this pins both against each other.
    check("native backend == sim oracle", 48, |g| {
        let c0 = g.usize_in(1, 2);
        let c1 = g.usize_in(1, 4);
        let c2 = g.usize_in(1, 4);
        let c3 = g.usize_in(1, 4);
        let classes = g.usize_in(2, 6);
        let params = ModelParams {
            convs: vec![
                random_conv(g, c0, c1),
                random_conv(g, c1, c2),
                random_conv(g, c2, c3),
            ],
            fc: FcLayer {
                out_n: classes,
                in_n: c3 * 4,
                weights: (0..classes * c3 * 4)
                    .map(|_| (g.rng().below(7) as i32 - 3) as i8)
                    .collect(),
                bias: (0..classes).map(|_| g.rng().below(65) as i32 - 32).collect(),
            },
            in_scale: 1.0,
        };
        let batch = g.usize_in(1, 3);
        // spatial sizes after each conv on the 8×8 input (2×2 pool after
        // every conv but the last): 64, 16, 4 output features per channel
        let spatial = [64usize, 16, 4];
        let ocs = [c1, c2, c3];
        let mut masks = LayerMasks {
            conv: [
                MaskPair::identity(spatial[0], c1),
                MaskPair::identity(spatial[1], c2),
                MaskPair::identity(spatial[2], c3),
            ],
            fc: MaskPair::identity(batch, classes),
        };
        // random stuck-mask sets over conv output features...
        for _ in 0..g.usize_in(0, 6) {
            let layer = g.usize_in(0, 2);
            let sp = g.usize_in(0, spatial[layer] - 1);
            let oc = g.usize_in(0, ocs[layer] - 1);
            let m = sample_stuck_mask(g.rng(), 1e-3, 144);
            masks.conv[layer].set(sp, oc, m);
        }
        // ...and fc outputs (identical across batch rows: same silicon)
        for _ in 0..g.usize_in(0, 2) {
            let n = g.usize_in(0, classes - 1);
            let m = sample_stuck_mask(g.rng(), 1e-3, 144);
            for b in 0..batch {
                masks.fc.set(b, n, m);
            }
        }
        let images: Vec<Vec<i8>> = (0..batch)
            .map(|_| {
                (0..c0 * 64)
                    .map(|_| (g.rng().below(256) as i32 - 128) as i8)
                    .collect()
            })
            .collect();
        let backend = NativeBackend::new(params.clone());
        let mut x = Vec::new();
        for img in &images {
            x.extend(img.iter().map(|&v| v as i32));
        }
        let mut inputs = vec![I32Tensor::new(vec![batch, c0, 8, 8], x)];
        inputs.extend(masks.to_tensors());
        let logits = backend.execute_i32(&inputs).unwrap();
        assert_eq!(logits.shape, vec![batch, classes]);
        for (b, img) in images.iter().enumerate() {
            let want = oracle_logits(&params, img, &masks);
            assert_eq!(
                &logits.data[b * classes..(b + 1) * classes],
                &want[..],
                "batch row {b}"
            );
        }
    });
}

#[test]
fn prop_montecarlo_thread_invariance() {
    // Same seed → same per-config outcome regardless of fan-out width.
    check("thread invariance", 20, |g| {
        let dims = Dims::new(16, 16);
        let per = g.f64_in(0.0, 0.1);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let run = |threads| {
            hyca::faults::montecarlo::map_configs(
                seed,
                48,
                dims,
                per,
                FaultModel::Random,
                threads,
                |_, cfg| cfg.count(),
            )
        };
        assert_eq!(run(1), run(7));
    });
}

#[test]
fn prop_serve_batched_equals_sequential_and_is_worker_invariant() {
    // The serving bit-exactness contract: for random request streams,
    // (a) the dynamically-batched pipeline predicts exactly what a
    // sequential `Engine::predict_batch` produces on the same images,
    // and (b) the whole report — predictions AND metrics — is
    // invariant to the executor thread count (the serve extension of
    // the thread-invariance assertion above).
    check("serve == sequential, worker invariant", 8, |g| {
        // built inside the property: `Box<dyn Backend>` is not
        // `RefUnwindSafe`, so the engine cannot be captured across the
        // harness's catch_unwind boundary (construction is cheap).
        let engine = std::sync::Arc::new(hyca::inference::Engine::builtin());
        let max_batch = g.usize_in(1, 5);
        let lanes = g.usize_in(1, 3);
        let clients = g.usize_in(1, 6).max(lanes);
        let cfg = hyca::serve::ServeConfig {
            seed: g.usize_in(0, 1 << 20) as u64,
            dims: Dims::new(8, 8),
            lanes,
            max_batch,
            max_wait_cycles: g.usize_in(0, 10_000) as u64,
            clients,
            think_cycles: g.usize_in(0, 2_000) as u64,
            total_requests: g.usize_in(4, 24),
            queue_cap: clients,
            executor_threads: 1,
            windows: g.usize_in(1, 6),
            faults: None,
        };
        let narrow = hyca::serve::run(&engine, &cfg).unwrap();
        // (a) batched == sequential on the same images, same masks
        let geometry = engine.geometry();
        let identity = hyca::inference::LayerMasks::identity(&geometry).with_fc_rows(1);
        let records = {
            let t = hyca::serve::simulate_timeline(&engine, &cfg);
            t.requests
        };
        assert_eq!(records.len(), narrow.predictions.len());
        for r in &records {
            let img = engine.eval.images[r.image_idx].clone();
            let seq = engine.predict_batch(&[img], &identity).unwrap()[0];
            assert_eq!(
                narrow.predictions[r.id], seq,
                "request {} diverged from sequential inference",
                r.id
            );
        }
        // (b) executor width changes nothing
        let mut wide_cfg = cfg.clone();
        wide_cfg.executor_threads = g.usize_in(2, 6);
        let wide = hyca::serve::run(&engine, &wide_cfg).unwrap();
        assert_eq!(narrow.digest(), wide.digest());
    });
}

#[test]
fn prop_worksteal_executor_is_invariant_to_mode_width_and_affinity() {
    // The work-stealing extension of the executor-invariance contract:
    // for random fleet workloads, every executor topology — legacy
    // shared queue, static partition (steal off), mutex work stealing,
    // lock-free work stealing — at random thread counts, chip counts,
    // affinity maps and home-set widths produces prediction vectors
    // bit-identical to the 1-thread shared-queue reference.
    use hyca::serve::executor::{self, DequeImpl, ExecMode, ExecPlan};
    check("executor modes/widths/affinity agree", 6, |g| {
        let engine = std::sync::Arc::new(hyca::inference::Engine::builtin());
        let n_chips = g.usize_in(1, 5);
        let clients = g.usize_in(1, 3) * n_chips;
        let cfg = hyca::fleet::FleetConfig {
            seed: g.usize_in(0, 1 << 20) as u64,
            chips: vec![
                hyca::fleet::ChipSpec {
                    dims: Dims::new(8, 8),
                    lanes: g.usize_in(1, 3),
                };
                n_chips
            ],
            policy: *g.choose(&hyca::fleet::RoutingPolicy::all()),
            max_batch: g.usize_in(1, 5),
            max_wait_cycles: g.usize_in(0, 10_000) as u64,
            clients,
            think_cycles: g.usize_in(0, 1_000) as u64,
            total_requests: g.usize_in(4, 8 * n_chips.max(1)),
            queue_cap: clients,
            executor_threads: 1,
            home_set: 1,
            windows: 4,
            faults: None,
            lifecycle: hyca::fleet::LifecyclePolicy::NEVER,
            open_loop: None,
            admission: None,
            autoscale: None,
        };
        let timeline = hyca::fleet::simulate_fleet(&engine, &cfg);
        let jobs: Vec<&hyca::serve::BatchJob> = timeline.jobs.iter().map(|j| &j.job).collect();
        let affinity: Vec<usize> = timeline.jobs.iter().map(|j| j.chip).collect();
        let reference = executor::execute(
            &engine,
            &jobs,
            None,
            1,
            ExecMode::SharedQueue,
            cfg.queue_cap,
        )
        .unwrap()
        .predictions;
        for _ in 0..3 {
            let threads = g.usize_in(1, 7);
            let mode = *g.choose(&[
                ExecMode::SharedQueue,
                ExecMode::WorkSteal { steal: false },
                ExecMode::WorkSteal { steal: true },
            ]);
            let deque = *g.choose(&[DequeImpl::Mutex, DequeImpl::LockFree]);
            let home_set = g.usize_in(1, 3);
            let aff = if g.bool(0.5) { Some(affinity.as_slice()) } else { None };
            let plan = ExecPlan {
                threads,
                mode,
                deque,
                affinity: aff,
                home_set,
                queue_cap: cfg.queue_cap,
            };
            let got = executor::execute_plan(&engine, &jobs, &plan).unwrap();
            assert_eq!(
                got.predictions, reference,
                "{} threads {threads} chips {n_chips} home_set {home_set} diverged",
                plan.label()
            );
        }
        // end to end: the fleet's affinity-driven run matches the
        // legacy-path predictions too
        let report = hyca::fleet::run(&engine, &cfg).unwrap();
        let flat: Vec<usize> = timeline
            .requests
            .iter()
            .map(|r| reference[r.batch_id][r.slot])
            .collect();
        assert_eq!(report.predictions, flat, "fleet::run diverged from reference");
    });
}

#[test]
fn prop_scenario_spec_round_trips_through_canonical_text() {
    // The scenario-format contract (DESIGN.md §7): for every valid
    // spec, parse(to_canonical_string(s)) == s and the canonical
    // rendering is a fixpoint — so `.scn` files and spec hashes are
    // stable identities.
    use hyca::fleet::RoutingPolicy;
    use hyca::scenario::{Driver, Knob, ScenarioBuilder, ScenarioSpec, SweepAxis};
    use hyca::serve::loadgen::RateCurve;
    check("scenario canonical round-trip", 150, |g| {
        let serve = g.bool(0.4);
        let name: String = (0..g.usize_in(3, 12))
            .map(|_| {
                const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_-";
                CHARS[g.usize_in(0, CHARS.len() - 1)] as char
            })
            .collect();
        let mut b = ScenarioBuilder::new(&name)
            .driver(if serve { Driver::Serve } else { Driver::Fleet })
            .seed(g.usize_in(0, 1 << 30) as u64)
            .think_cycles(g.usize_in(0, 2_000) as u64)
            .max_batch(g.usize_in(1, 16))
            .max_wait_cycles(g.usize_in(1, 10_000) as u64)
            .windows(g.usize_in(1, 10));
        let n_chips = if serve { 1 } else { g.usize_in(1, 4) };
        for _ in 0..n_chips {
            let d = *g.choose(&[8usize, 16, 32]);
            b = b.chip(d, d, g.usize_in(1, 4));
        }
        b = if g.bool(0.5) {
            b.clients_fixed(g.usize_in(1, 32))
        } else {
            b.clients_saturate(g.usize_in(1, 3), g.usize_in(1, 8))
        };
        let full = g.usize_in(1, 512);
        let smoke = g.usize_in(1, full);
        b = if !serve && g.bool(0.5) {
            b.requests_per_chip(full, smoke)
        } else {
            b.requests(full, smoke)
        };
        let with_faults = g.bool(0.6);
        if with_faults {
            b = b
                .fault_arrivals(
                    g.usize_in(1_000, 100_000) as f64,
                    g.usize_in(1_000, 100_000) as f64,
                    g.usize_in(0, 200_000) as u64,
                    g.usize_in(0, 200_000) as u64,
                    g.usize_in(0, 8),
                )
                .scan_period(
                    g.usize_in(1_000, 20_000) as u64,
                    g.usize_in(1_000, 20_000) as u64,
                );
        }
        if !serve && g.bool(0.5) {
            let enter = g.usize_in(1, 4);
            let exit = g.usize_in(1, enter);
            b = b.hysteresis(enter, exit, g.usize_in(0, 10_000) as u64);
        }
        if serve {
            if g.bool(0.6) {
                b = b.sweep(SweepAxis::Lanes(Knob::split(
                    vec![1, g.usize_in(2, 8)],
                    vec![1],
                )));
            }
            if g.bool(0.6) {
                b = b.sweep(SweepAxis::MaxBatch(Knob::flat(vec![1, g.usize_in(2, 32)])));
            }
        } else {
            // chips and topology axes are mutually exclusive
            // (ScenarioError::ConflictingAxes), so pick at most one
            let swept_chips = g.bool(0.5);
            if swept_chips {
                b = b.sweep(SweepAxis::Chips(Knob::split(
                    vec![1, g.usize_in(2, 8)],
                    vec![g.usize_in(1, 4)],
                )));
            } else if g.bool(0.4) {
                b = b.sweep(SweepAxis::Topology(Knob::flat(vec![
                    vec![Dims::new(8, 8); g.usize_in(1, 3)],
                    vec![Dims::new(8, 8), Dims::new(16, 16)],
                ])));
            }
            if g.bool(0.5) {
                b = b.sweep(SweepAxis::Router(RoutingPolicy::all().to_vec()));
            }
            if with_faults && g.bool(0.3) {
                b = b.sweep(SweepAxis::FaultMean(Knob::flat(vec![
                    g.usize_in(1_000, 50_000) as f64,
                ])));
            }
            if with_faults && g.bool(0.5) {
                b = b.spatial(hyca::faults::Spatial::Clustered);
            }
            // PR 6 knobs: open-loop mode, SLO/admission, autoscaling —
            // all must survive the canonical text like everything else
            if g.bool(0.4) {
                let curve = match g.usize_in(0, 2) {
                    0 => RateCurve::Constant { per_kcycle: g.usize_in(1, 20) as f64 },
                    1 => RateCurve::Diurnal {
                        base_per_kcycle: g.usize_in(1, 10) as f64,
                        amplitude: g.usize_in(0, 10) as f64 / 10.0,
                        period_cycles: g.usize_in(1_000, 100_000) as u64,
                    },
                    _ => RateCurve::FlashCrowd {
                        base_per_kcycle: g.usize_in(1, 10) as f64,
                        peak_mult: g.usize_in(1, 20) as f64,
                        start_cycle: g.usize_in(0, 50_000) as u64,
                        len_cycles: g.usize_in(1_000, 50_000) as u64,
                    },
                };
                let h_full = g.usize_in(10_000, 200_000) as u64;
                b = b.open_mode(curve, h_full, g.usize_in(5_000, 10_000) as u64);
                if g.bool(0.4) {
                    b = b.sweep(SweepAxis::RateScale(Knob::flat(vec![
                        1.0,
                        g.usize_in(3, 9) as f64 / 2.0,
                    ])));
                }
            }
            if g.bool(0.5) {
                b = b.slo(g.usize_in(1_000, 200_000) as u64).admission(g.bool(0.7));
                if g.bool(0.5) {
                    let min = g.usize_in(1, n_chips);
                    let max = g.usize_in(min, n_chips);
                    let down = g.usize_in(0, 4);
                    let up = g.usize_in(down + 1, down + 8);
                    b = b.autoscale(
                        min,
                        max,
                        up,
                        down,
                        g.usize_in(0, 30_000) as u64,
                        g.usize_in(1_000, 10_000) as u64,
                    );
                }
            }
        }
        let spec = b.build().expect("generated spec must validate");
        let text = spec.to_canonical_string();
        let back = ScenarioSpec::parse(&text)
            .unwrap_or_else(|e| panic!("canonical text failed to parse: {e}\n{text}"));
        assert_eq!(back, spec, "round trip changed the spec:\n{text}");
        assert_eq!(back.to_canonical_string(), text, "canonical must be a fixpoint");
        assert_eq!(back.spec_hash(), spec.spec_hash());
    });
}

#[test]
fn prop_tracing_is_inert_and_deterministic() {
    // The telemetry contract (DESIGN.md §10): a trace sink is a pure
    // observer — attaching one changes no prediction, no metric, no
    // digest — and the deterministic stream it captures is a pure
    // function of simulated cycles, byte-identical at any executor
    // width.
    check("tracing inert + worker-invariant stream", 6, |g| {
        let engine = std::sync::Arc::new(hyca::inference::Engine::builtin());
        let n_chips = g.usize_in(1, 4);
        let clients = g.usize_in(1, 3) * n_chips;
        let cfg = hyca::fleet::FleetConfig {
            seed: g.usize_in(0, 1 << 20) as u64,
            chips: vec![
                hyca::fleet::ChipSpec {
                    dims: Dims::new(8, 8),
                    lanes: g.usize_in(1, 3),
                };
                n_chips
            ],
            policy: *g.choose(&hyca::fleet::RoutingPolicy::all()),
            max_batch: g.usize_in(1, 5),
            max_wait_cycles: g.usize_in(0, 10_000) as u64,
            clients,
            think_cycles: g.usize_in(0, 1_000) as u64,
            total_requests: g.usize_in(4, 8 * n_chips),
            queue_cap: clients,
            executor_threads: 1,
            home_set: 1,
            windows: 4,
            faults: None,
            lifecycle: hyca::fleet::LifecyclePolicy::NEVER,
            open_loop: None,
            admission: None,
            autoscale: None,
        };
        let plain = hyca::fleet::run(&engine, &cfg).unwrap();
        let mut sink = hyca::obs::MemorySink::default();
        let traced = hyca::fleet::run_traced(&engine, &cfg, &mut sink).unwrap();
        assert_eq!(traced.digest(), plain.digest(), "tracing changed the metrics");
        assert_eq!(traced.predictions, plain.predictions);
        assert!(!sink.events.is_empty(), "a traced run must emit events");
        // the deterministic stream is invariant to the executor width
        let mut wide_cfg = cfg.clone();
        wide_cfg.executor_threads = g.usize_in(2, 6);
        let mut wide_sink = hyca::obs::MemorySink::default();
        let wide = hyca::fleet::run_traced(&engine, &wide_cfg, &mut wide_sink).unwrap();
        assert_eq!(wide.digest(), plain.digest());
        assert_eq!(
            hyca::obs::render_stream(&wide_sink.events),
            hyca::obs::render_stream(&sink.events),
            "executor width leaked into the trace stream"
        );
    });
}

#[test]
fn prop_snapshot_resume_equals_the_uninterrupted_run() {
    // The event-sourcing contract (DESIGN.md §12): for random fleet
    // configurations and a random snapshot cadence, resuming from any
    // captured snapshot and replaying the rest of the run is
    // byte-identical to the uninterrupted run — same event-log tail,
    // same request records, same dispatched batches (mask epochs
    // included) — and the snapshot byte format round-trips while its
    // FNV-1a integrity hash rejects a random single-bit flip.
    use hyca::engine::{ClusterEngine, Snapshot};
    use hyca::obs::{recorder, FlightRecorder, NullSink, Probe};
    check("snapshot/resume ≡ full run", 6, |g| {
        let engine = std::sync::Arc::new(hyca::inference::Engine::builtin());
        let n_chips = g.usize_in(1, 4);
        let clients = g.usize_in(1, 3) * n_chips;
        let faults = if g.bool(0.4) {
            Some(hyca::serve::FaultPlan {
                mean_interarrival_cycles: g.usize_in(2_000, 30_000) as f64,
                horizon_cycles: g.usize_in(0, 60_000) as u64,
                scan_period_cycles: g.usize_in(1_000, 8_000) as u64,
                group_width: 8,
                fpt_capacity: g.usize_in(1, 8),
                max_arrivals: g.usize_in(0, 6),
                spatial: if g.bool(0.5) {
                    hyca::faults::Spatial::Clustered
                } else {
                    hyca::faults::Spatial::Random
                },
            })
        } else {
            None
        };
        let cfg = hyca::fleet::FleetConfig {
            seed: g.usize_in(0, 1 << 20) as u64,
            chips: vec![
                hyca::fleet::ChipSpec {
                    dims: Dims::new(8, 8),
                    lanes: g.usize_in(1, 3),
                };
                n_chips
            ],
            policy: *g.choose(&hyca::fleet::RoutingPolicy::all()),
            max_batch: g.usize_in(1, 5),
            max_wait_cycles: g.usize_in(0, 10_000) as u64,
            clients,
            think_cycles: g.usize_in(0, 1_000) as u64,
            total_requests: g.usize_in(8, 8 * n_chips.max(2)),
            queue_cap: clients,
            executor_threads: 1,
            home_set: 1,
            windows: 4,
            faults,
            lifecycle: hyca::fleet::LifecyclePolicy::NEVER,
            open_loop: None,
            admission: None,
            autoscale: None,
        };
        let mut rec = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
        let mut sink = NullSink;
        let mut probe = Probe { sink: &mut sink, rec: &mut rec };
        let mut core = ClusterEngine::new(&engine, &cfg, &mut probe);
        let every = g.usize_in(1, 25) as u64 * 1_000;
        let snaps = core.run_with_snapshots(&mut probe, every);
        let log = core.log().to_vec();
        let base = core.finish(&mut probe);
        for snap in &snaps {
            // byte round-trip + corruption detection
            let bytes = snap.to_bytes();
            assert_eq!(&Snapshot::from_bytes(&bytes).expect("round-trip"), snap);
            let bit = g.usize_in(0, bytes.len() * 8 - 1);
            let mut bad = bytes.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            assert!(
                Snapshot::from_bytes(&bad).is_err(),
                "single-bit flip at bit {bit} went undetected"
            );
            // resume ≡ full run, event-for-event and job-for-job
            let mut rec2 = FlightRecorder::new(recorder::DEFAULT_CAPACITY);
            let mut sink2 = NullSink;
            let mut probe2 = Probe { sink: &mut sink2, rec: &mut rec2 };
            let mut resumed = ClusterEngine::resume(&engine, &cfg, snap).expect("resume");
            resumed.run(&mut probe2);
            let off = snap.events_logged as usize;
            assert_eq!(
                resumed.log(),
                &log[off..],
                "tail diverged resuming @{} (every={every})",
                snap.label_cycle
            );
            let t = resumed.finish(&mut probe2);
            assert_eq!(t.requests, base.requests);
            assert_eq!(t.total_cycles, base.total_cycles);
            assert_eq!(t.events, base.events);
            assert_eq!(t.jobs.len(), base.jobs.len());
            for (r, b) in t.jobs.iter().zip(&base.jobs) {
                assert_eq!((r.chip, r.job.id, r.job.lane), (b.chip, b.job.id, b.job.lane));
                assert_eq!(r.job.image_idxs, b.job.image_idxs);
                assert_eq!(
                    (r.job.start_cycle, r.job.end_cycle),
                    (b.job.start_cycle, b.job.end_cycle)
                );
                assert_eq!(*r.job.masks, *b.job.masks, "mask epochs diverged");
            }
        }
    });
}

#[test]
fn prop_one_chip_fleet_degenerates_to_serve() {
    // The fleet degeneracy contract: for random serving configurations
    // — load shape, batcher settings, lanes, and optional mid-run
    // fault plans — a 1-chip fleet under round-robin routing with
    // draining disabled reproduces `serve` exactly: same request
    // records (cycle timeline) and same per-request predictions.
    check("1-chip fleet == serve", 8, |g| {
        let engine = std::sync::Arc::new(hyca::inference::Engine::builtin());
        let max_batch = g.usize_in(1, 5);
        let lanes = g.usize_in(1, 3);
        let clients = g.usize_in(1, 6).max(lanes);
        let faults = if g.bool(0.5) {
            Some(hyca::serve::FaultPlan {
                mean_interarrival_cycles: g.usize_in(2_000, 30_000) as f64,
                horizon_cycles: g.usize_in(0, 60_000) as u64,
                scan_period_cycles: g.usize_in(1_000, 8_000) as u64,
                group_width: 8,
                fpt_capacity: g.usize_in(1, 8),
                max_arrivals: g.usize_in(0, 6),
                spatial: if g.bool(0.5) {
                    hyca::faults::Spatial::Clustered
                } else {
                    hyca::faults::Spatial::Random
                },
            })
        } else {
            None
        };
        let cfg = hyca::serve::ServeConfig {
            seed: g.usize_in(0, 1 << 20) as u64,
            dims: Dims::new(8, 8),
            lanes,
            max_batch,
            max_wait_cycles: g.usize_in(0, 10_000) as u64,
            clients,
            think_cycles: g.usize_in(0, 2_000) as u64,
            total_requests: g.usize_in(4, 24),
            queue_cap: clients,
            executor_threads: 2,
            windows: g.usize_in(1, 6),
            faults,
        };
        let serve_t = hyca::serve::simulate_timeline(&engine, &cfg);
        let fleet_t =
            hyca::fleet::simulate_fleet(&engine, &hyca::fleet::FleetConfig::degenerate(&cfg));
        assert_eq!(fleet_t.requests, serve_t.requests, "cycle timelines diverged");
        assert_eq!(fleet_t.total_cycles, serve_t.total_cycles);
        assert_eq!(fleet_t.jobs.len(), serve_t.jobs.len());
        for (f, s) in fleet_t.jobs.iter().zip(&serve_t.jobs) {
            assert_eq!(f.chip, 0);
            assert_eq!(f.job.image_idxs, s.image_idxs);
            assert_eq!((f.job.start_cycle, f.job.end_cycle), (s.start_cycle, s.end_cycle));
            assert_eq!(f.job.lane, s.lane);
            assert_eq!(*f.job.masks, *s.masks, "mask epochs diverged");
        }
        // end to end: identical predictions
        let serve_report = hyca::serve::run(&engine, &cfg).unwrap();
        let fleet_report = hyca::fleet::run(&engine, &hyca::fleet::FleetConfig::degenerate(&cfg))
            .unwrap();
        assert_eq!(fleet_report.predictions, serve_report.predictions);
        assert_eq!(fleet_report.accuracy, serve_report.accuracy);
    });
}
