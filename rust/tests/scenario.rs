//! Scenario-API acceptance tests.
//!
//! 1. **Compatibility pinning**: the `steady_state` / `burst` /
//!    `fleet_default` / `degraded_continuity` presets lower to
//!    *exactly* the `ServeConfig`s / `FleetConfig`s the pre-scenario
//!    drivers (PR 2 / PR 3) hard-coded — frozen here as literals, so
//!    the configs (and therefore every bench byte) cannot drift.
//! 2. **Canonical format**: the committed `scenarios/*.scn` files
//!    parse to the registered presets; presets round-trip through the
//!    canonical text (the property test in `proptests.rs` sweeps
//!    random specs).
//! 3. **Typed validation**: bad dims, empty sweeps and inverted
//!    hysteresis thresholds are rejected with the documented errors.
//! 4. **Mixed fleet**: `BENCH_fleet.json` (schema v2) carries the
//!    heterogeneous-dims grid with the load-imbalance routing-quality
//!    column, and the health-weighted policy beats round-robin on it.

use hyca::array::Dims;
use hyca::coordinator::{exp_fleet, exp_scenario, RunOpts};
use hyca::fleet::{ChipSpec, FleetConfig, LifecyclePolicy, RoutingPolicy};
use hyca::scenario::{
    presets, Cell, Knob, ScenarioBuilder, ScenarioError, ScenarioSpec, SweepAxis,
};
use hyca::serve::{FaultPlan, ServeConfig};

const SEED: u64 = 0xC0FFEE;

/// The PR 2 serve grid cell, verbatim (exp_serve.rs @ 7ce6eef).
fn legacy_serve_grid_cell(
    seed: u64,
    lanes: usize,
    max_batch: usize,
    smoke: bool,
    threads: usize,
) -> ServeConfig {
    let clients = (lanes * max_batch * 2).max(4);
    ServeConfig {
        seed,
        dims: Dims::new(8, 8),
        lanes,
        max_batch,
        max_wait_cycles: 8_000,
        clients,
        think_cycles: 500,
        total_requests: if smoke { 64 } else { 192 },
        queue_cap: clients,
        executor_threads: threads,
        windows: 4,
        faults: None,
    }
}

/// The PR 2 serve fault scenario, verbatim.
fn legacy_serve_scenario(seed: u64, smoke: bool, threads: usize) -> ServeConfig {
    ServeConfig {
        seed,
        dims: Dims::new(8, 8),
        lanes: 2,
        max_batch: 8,
        max_wait_cycles: 8_000,
        clients: 16,
        think_cycles: 500,
        total_requests: if smoke { 96 } else { 384 },
        queue_cap: 16,
        executor_threads: threads,
        windows: 10,
        faults: Some(FaultPlan {
            mean_interarrival_cycles: if smoke { 20_000.0 } else { 60_000.0 },
            horizon_cycles: if smoke { 60_000 } else { 200_000 },
            scan_period_cycles: if smoke { 4_000 } else { 16_000 },
            group_width: 8,
            fpt_capacity: 8,
            max_arrivals: 6,
            spatial: hyca::faults::Spatial::Random,
        }),
    }
}

/// The PR 3 fleet grid cell, verbatim (exp_fleet.rs @ f983b9f); the
/// `drain_threshold: NEVER_DRAIN` field became
/// `lifecycle: LifecyclePolicy::NEVER`.
fn legacy_fleet_cell(
    seed: u64,
    n_chips: usize,
    policy: RoutingPolicy,
    smoke: bool,
    threads: usize,
) -> FleetConfig {
    let clients = (n_chips * 2 * 8).max(8);
    FleetConfig {
        seed,
        chips: vec![ChipSpec { dims: Dims::new(8, 8), lanes: 2 }; n_chips],
        policy,
        max_batch: 8,
        max_wait_cycles: 8_000,
        clients,
        think_cycles: 500,
        total_requests: if smoke { 32 * n_chips } else { 96 * n_chips },
        queue_cap: clients,
        executor_threads: threads,
        home_set: 1,
        windows: 4,
        faults: None,
        lifecycle: LifecyclePolicy::NEVER,
        open_loop: None,
        admission: None,
        autoscale: None,
    }
}

/// The PR 3 drain/re-admit scenario, verbatim (`drain_threshold: 2`
/// became the equivalent single-threshold policy).
fn legacy_fleet_scenario(seed: u64, smoke: bool, threads: usize) -> FleetConfig {
    FleetConfig {
        seed,
        chips: vec![ChipSpec { dims: Dims::new(8, 8), lanes: 2 }; 3],
        policy: RoutingPolicy::HealthWeighted,
        max_batch: 8,
        max_wait_cycles: 8_000,
        clients: 24,
        think_cycles: 500,
        total_requests: if smoke { 192 } else { 432 },
        queue_cap: 24,
        executor_threads: threads,
        home_set: 1,
        windows: 10,
        faults: Some(FaultPlan {
            mean_interarrival_cycles: if smoke { 6_000.0 } else { 20_000.0 },
            horizon_cycles: if smoke { 40_000 } else { 160_000 },
            scan_period_cycles: if smoke { 4_000 } else { 16_000 },
            group_width: 8,
            fpt_capacity: 8,
            max_arrivals: 6,
            spatial: hyca::faults::Spatial::Random,
        }),
        lifecycle: LifecyclePolicy::single(2),
        open_loop: None,
        admission: None,
        autoscale: None,
    }
}

#[test]
fn steady_state_lowers_to_the_pr2_grid_configs() {
    let spec = presets::preset("steady_state").unwrap();
    for (smoke, lanes_sweep, batch_sweep) in [
        (false, vec![1usize, 2, 4, 8], vec![1usize, 8, 32]),
        (true, vec![1, 4], vec![1, 8]),
    ] {
        let cells = spec.cells(smoke);
        let mut want = Vec::new();
        for &l in &lanes_sweep {
            for &b in &batch_sweep {
                want.push(legacy_serve_grid_cell(SEED, l, b, smoke, 3));
            }
        }
        let got: Vec<ServeConfig> = cells
            .iter()
            .map(|c| hyca::scenario::lower_serve(&spec, c, smoke, SEED, 3).unwrap())
            .collect();
        assert_eq!(got, want, "smoke={smoke}: the grid drifted from PR 2");
    }
}

#[test]
fn burst_lowers_to_the_pr2_fault_scenario_config() {
    let spec = presets::preset("burst").unwrap();
    for smoke in [false, true] {
        let got =
            hyca::scenario::lower_serve(&spec, &Cell::base(&spec), smoke, SEED, 2).unwrap();
        assert_eq!(got, legacy_serve_scenario(SEED, smoke, 2), "smoke={smoke}");
    }
}

#[test]
fn fleet_default_lowers_to_the_pr3_grid_configs() {
    let spec = presets::preset("fleet_default").unwrap();
    for (smoke, chip_sweep) in [(false, vec![1usize, 2, 4, 8]), (true, vec![1, 4])] {
        let mut want = Vec::new();
        for &n in &chip_sweep {
            for policy in RoutingPolicy::all() {
                want.push(legacy_fleet_cell(SEED, n, policy, smoke, 3));
            }
        }
        let got: Vec<FleetConfig> = spec
            .cells(smoke)
            .iter()
            .map(|c| hyca::scenario::lower_fleet(&spec, c, smoke, SEED, 3))
            .collect();
        assert_eq!(got, want, "smoke={smoke}: the grid drifted from PR 3");
    }
}

#[test]
fn degraded_continuity_lowers_to_the_pr3_drain_scenario_config() {
    let spec = presets::preset("degraded_continuity").unwrap();
    for smoke in [false, true] {
        let got = hyca::scenario::lower_fleet(&spec, &Cell::base(&spec), smoke, SEED, 2);
        assert_eq!(got, legacy_fleet_scenario(SEED, smoke, 2), "smoke={smoke}");
    }
}

#[test]
fn scn_files_parse_to_the_registered_presets() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    for name in presets::names() {
        let path = dir.join(format!("{name}.scn"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let spec = ScenarioSpec::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert_eq!(
            spec,
            presets::preset(name).unwrap(),
            "{name}.scn drifted from the registered preset — regenerate with \
             to_canonical_string()"
        );
    }
}

#[test]
fn presets_round_trip_and_hash_stably() {
    for name in presets::names() {
        let spec = presets::preset(name).unwrap();
        let text = spec.to_canonical_string();
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back, spec, "{name}");
        assert_eq!(back.spec_hash(), spec.spec_hash(), "{name}");
    }
}

#[test]
fn validation_rejects_bad_dims_empty_sweep_and_inverted_hysteresis() {
    assert_eq!(
        ScenarioBuilder::new("bad").chip(8, 0, 2).build(),
        Err(ScenarioError::BadDims { chip: 0, rows: 8, cols: 0 })
    );
    assert_eq!(
        ScenarioBuilder::new("bad")
            .chip(8, 8, 2)
            .sweep(SweepAxis::Router(vec![]))
            .build(),
        Err(ScenarioError::EmptySweep { axis: "router" })
    );
    assert_eq!(
        ScenarioBuilder::new("bad").chip(8, 8, 2).hysteresis(1, 2, 0).build(),
        Err(ScenarioError::ExitAboveEnter { enter: 1, exit: 2 })
    );
    assert_eq!(
        ScenarioBuilder::new("bad").chip(8, 8, 2).requests(0, 4).build(),
        Err(ScenarioError::ZeroRequests)
    );
    // the same errors surface through the text format
    let text = "scenario \"bad\"\n[topology]\nchip = 8x8 lanes=2\n\
                [policy]\ndrain_enter = 1\ndrain_exit = 2\n";
    assert_eq!(
        ScenarioSpec::parse(text).unwrap_err(),
        ScenarioError::ExitAboveEnter { enter: 1, exit: 2 }
    );
}

#[test]
fn bench_fleet_v2_carries_the_mixed_fleet_section() {
    let opts = RunOpts {
        seed: SEED,
        threads: 2,
        out_dir: std::env::temp_dir().join("hyca_scenario_results"),
        builtin_model: true,
        ..RunOpts::default()
    };
    let json = exp_fleet::bench_json(&opts, true).unwrap();
    assert!(json.contains("\"schema\": \"hyca-fleet-bench-v2\""));
    assert!(json.contains("\"mixed_fleet\": ["));
    assert!(json.contains("\"topology\": \"3*8x8\""));
    assert!(json.contains("\"topology\": \"8x8+16x16+32x32\""));
    assert!(json.contains("\"load_imbalance\":"));
    // no wall-clock fields, ever
    for forbidden in ["seconds", "wall", "ns_per"] {
        assert!(!json.contains(forbidden), "wall-clock field {forbidden:?}");
    }
}

#[test]
fn health_weighted_routing_beats_round_robin_on_the_mixed_topology() {
    let spec = presets::preset("mixed_fleet").unwrap();
    let run = exp_scenario::run_cells(&spec, SEED, 2, true).unwrap();
    let exp_scenario::ScenarioRun::Fleet(results) = run else {
        panic!("mixed_fleet is a fleet scenario")
    };
    let imbalance = |policy: RoutingPolicy| -> f64 {
        results
            .iter()
            .find(|(c, _)| {
                c.policy == policy
                    && c.labels.iter().any(|(k, v)| *k == "topology" && v == "8x8+16x16+32x32")
            })
            .map(|(_, r)| r.load_imbalance())
            .expect("mixed topology cell present in smoke grid")
    };
    let rr = imbalance(RoutingPolicy::RoundRobin);
    let hw = imbalance(RoutingPolicy::HealthWeighted);
    assert!(
        hw < rr,
        "health-weighted must track the weight-optimal split better than \
         round-robin on heterogeneous arrays (hw={hw:.4}, rr={rr:.4})"
    );
    // round-robin's even split is visibly off the optimal on a fleet
    // whose largest chip dwarfs the smallest
    assert!(rr > 0.1, "rr={rr:.4}");
}

#[test]
fn uneven_faults_stress_grid_serves_every_request_under_hysteresis() {
    let spec = presets::preset("uneven_faults").unwrap();
    assert_eq!(
        spec.lifecycle,
        LifecyclePolicy { drain_enter: 2, drain_exit: 1, min_dwell_cycles: 8_000 }
    );
    let run = exp_scenario::run_cells(&spec, SEED, 2, true).unwrap();
    let exp_scenario::ScenarioRun::Fleet(results) = run else {
        panic!("uneven_faults is a fleet scenario")
    };
    assert_eq!(results.len(), 2, "smoke grid: 1 fault_mean × 2 policies");
    for (cell, report) in &results {
        // degraded continuity: the closed loop always serves its budget
        assert_eq!(
            report.total_requests,
            hyca::scenario::lower::total_requests(&spec, cell, true),
            "requests dropped under fault stress"
        );
        assert!(report.availability() <= 1.0);
    }
}

#[test]
fn spec_files_and_registry_agree_on_the_cli_surface() {
    // `repro scenario list` and CI's `scenario all --smoke` both walk
    // presets::names(); pin the registry contents so a rename is a
    // conscious, documented change
    assert_eq!(
        presets::names(),
        &[
            "steady_state",
            "burst",
            "fleet_default",
            "degraded_continuity",
            "mixed_fleet",
            "uneven_faults",
            "open_steady",
            "flash_crowd",
            "open_diurnal",
            "long_diurnal",
        ]
    );
    // parse errors carry line numbers for CLI diagnostics
    let err = ScenarioSpec::parse("scenario \"x\"\n???\n").unwrap_err();
    assert!(matches!(err, ScenarioError::Parse { line: 2, .. }), "{err}");
}

#[test]
fn knob_smoke_variants_reach_the_lowered_configs() {
    let spec = presets::preset("burst").unwrap();
    let full = hyca::scenario::lower_serve(&spec, &Cell::base(&spec), false, SEED, 1).unwrap();
    let smoke = hyca::scenario::lower_serve(&spec, &Cell::base(&spec), true, SEED, 1).unwrap();
    assert_eq!(full.total_requests, 384);
    assert_eq!(smoke.total_requests, 96);
    assert_eq!(full.faults.unwrap().mean_interarrival_cycles, 60_000.0);
    assert_eq!(smoke.faults.unwrap().mean_interarrival_cycles, 20_000.0);
    assert_eq!(full.faults.unwrap().scan_period_cycles, 16_000);
    assert_eq!(smoke.faults.unwrap().scan_period_cycles, 4_000);
    // smoke knobs are declared, not computed: the Knob type carries both
    let env = spec.faults.as_ref().unwrap();
    assert!(env.mean_interarrival_cycles.is_split());
    assert_eq!(*Knob::flat(7usize).at(true), 7);
}
