//! Telemetry acceptance tests (DESIGN.md §10).
//!
//! 1. **Golden stream determinism**: the rendered trace stream of the
//!    canonical serve and traffic scenarios is byte-identical at any
//!    `--workers` value and across repeated runs — everything is keyed
//!    to simulated cycles, never wall clock.
//! 2. **Observer inertness**: attaching a sink changes no metric and
//!    no prediction (the proptest in `proptests.rs` fuzzes this; here
//!    the canonical scenarios pin it).
//! 3. **Chrome export**: the `--trace` JSON is structurally sound —
//!    only `X`/`i`/`b`/`e`/`M` phases, and the flash-crowd trace
//!    actually shows the shed and scale-up story.
//! 4. **Nondet quarantine**: executor steals never appear in the
//!    deterministic stream or the export — they live on the separate
//!    nondet channel.

use hyca::coordinator::{exp_serve, exp_traffic, RunOpts};
use hyca::fleet;
use hyca::inference::Engine;
use hyca::obs::{render_stream, MemorySink};
use hyca::serve;
use std::sync::Arc;

const SEED: u64 = 0xC0FFEE;

fn opts(seed: u64, threads: usize) -> RunOpts {
    RunOpts {
        seed,
        threads,
        out_dir: std::env::temp_dir().join("hyca_obs_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

fn serve_stream(workers: usize) -> (String, f64) {
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_serve::scenario_config(SEED, true, workers);
    let mut sink = MemorySink::default();
    let report = serve::run_traced(&engine, &cfg, &mut sink).unwrap();
    (render_stream(&sink.events), report.accuracy)
}

fn traffic_stream(workers: usize) -> (String, Vec<hyca::obs::TracedEvent>) {
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_traffic::traffic_config("flash_crowd", SEED, true, workers);
    let mut sink = MemorySink::default();
    fleet::run_traced(&engine, &cfg, &mut sink).unwrap();
    (render_stream(&sink.events), sink.events)
}

#[test]
fn serve_trace_stream_is_byte_identical_at_any_worker_count() {
    let (narrow, acc1) = serve_stream(1);
    let (wide, acc8) = serve_stream(8);
    assert!(!narrow.is_empty(), "the burst scenario must emit events");
    assert_eq!(narrow, wide, "worker count leaked into the serve trace");
    assert_eq!(acc1, acc8);
    let (again, _) = serve_stream(1);
    assert_eq!(narrow, again, "the stream must replay from its seed");
    // the burst scenario's story is in the stream: faults arrive, the
    // scan detects them, remaps apply, requests flow
    for needle in [
        " request_enqueue ",
        " batch_formed ",
        " request_complete ",
        " fault_arrival ",
        " scan_detect ",
        " remap_applied ",
    ] {
        assert!(narrow.contains(needle), "missing {needle:?} in stream");
    }
}

#[test]
fn traffic_trace_stream_is_byte_identical_at_any_worker_count() {
    let (narrow, events) = traffic_stream(1);
    let (wide, _) = traffic_stream(8);
    assert_eq!(narrow, wide, "worker count leaked into the traffic trace");
    assert!(!events.is_empty());
    // flash crowd: admission control sheds and the autoscaler reacts
    for needle in [" shed ", " autoscale_tick ", " scale_up "] {
        assert!(narrow.contains(needle), "missing {needle:?} in stream");
    }
}

#[test]
fn tracing_leaves_the_canonical_reports_untouched() {
    let engine = Arc::new(Engine::builtin());
    // serve burst
    let scfg = exp_serve::scenario_config(SEED, true, 2);
    let plain = serve::run(&engine, &scfg).unwrap();
    let mut sink = MemorySink::default();
    let traced = serve::run_traced(&engine, &scfg, &mut sink).unwrap();
    assert_eq!(traced.digest(), plain.digest());
    assert_eq!(traced.predictions, plain.predictions);
    // traffic flash_crowd
    let tcfg = exp_traffic::traffic_config("flash_crowd", SEED, true, 2);
    let fplain = fleet::run(&engine, &tcfg).unwrap();
    let mut fsink = MemorySink::default();
    let ftraced = fleet::run_traced(&engine, &tcfg, &mut fsink).unwrap();
    assert_eq!(ftraced.digest(), fplain.digest());
    assert_eq!(ftraced.predictions, fplain.predictions);
}

#[test]
fn executor_steals_stay_on_the_nondet_channel() {
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_traffic::traffic_config("flash_crowd", SEED, true, 4);
    let mut sink = MemorySink::default();
    fleet::run_traced(&engine, &cfg, &mut sink).unwrap();
    // whatever the scheduler did, the deterministic stream is clean
    assert!(
        !render_stream(&sink.events).contains("executor_steal"),
        "steals leaked into the deterministic stream"
    );
    for e in &sink.nondet {
        assert!(
            matches!(e.event, hyca::obs::TraceEvent::ExecutorSteal { .. }),
            "only steals belong on the nondet channel"
        );
    }
}

#[test]
fn chrome_export_is_structurally_sound_and_worker_invariant() {
    let trace = exp_traffic::trace_json(&opts(SEED, 1), true).unwrap();
    let wide = exp_traffic::trace_json(&opts(SEED, 8), true).unwrap();
    assert_eq!(trace, wide, "worker count leaked into the Chrome export");
    assert!(trace.contains("\"traceEvents\": ["));
    assert!(trace.contains("1 trace us == 1 simulated cycle"));
    // the flash-crowd story survives the export
    for name in ["\"name\": \"shed\"", "\"name\": \"scale_up\"", "\"name\": \"batch\""] {
        assert!(trace.contains(name), "missing {name} in export");
    }
    // only the documented phases appear
    let mut phases = 0;
    for part in trace.split("\"ph\": \"").skip(1) {
        let ph = &part[..1];
        assert!(
            matches!(ph, "X" | "i" | "b" | "e" | "M"),
            "unexpected trace phase {ph:?}"
        );
        phases += 1;
    }
    assert!(phases > 0, "the export must contain events");
    // and steals never reach the export
    assert!(!trace.contains("executor_steal"));
}

#[test]
fn serve_and_fleet_exports_cover_their_scenarios() {
    let serve_trace = exp_serve::trace_json(&opts(SEED, 2), true).unwrap();
    assert!(serve_trace.contains("\"name\": \"fault_arrival\""));
    assert!(serve_trace.contains("\"name\": \"remap_applied\""));
    assert!(serve_trace.contains("serve/burst"));
    let fleet_trace = hyca::coordinator::exp_fleet::trace_json(&opts(SEED, 2), true).unwrap();
    assert!(fleet_trace.contains("\"name\": \"drained\""));
    assert!(fleet_trace.contains("fleet/degraded_continuity"));
}
