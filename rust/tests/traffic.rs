//! Open-loop traffic acceptance tests (`repro traffic`, DESIGN.md §9).
//!
//! 1. **Golden determinism**: `BENCH_traffic.json` is a pure function
//!    of the master seed — byte-identical at any `--workers` value and
//!    across repeated runs.
//! 2. **Degeneracy**: the `open_steady` preset (one chip far below
//!    saturation) recovers the closed-loop contract — zero shed,
//!    accuracy exactly 1.0 on everything offered.
//! 3. **Admission golden**: `flash_crowd` overloads a 4-chip fleet
//!    5× past capacity; the controller sheds, every *admitted* request
//!    still completes with accuracy 1.0, and SLO attainment on the
//!    admitted set stays high.
//! 4. **Flap guard**: the autoscaler's scale steps respect the dwell
//!    and never leave the `[min_chips, max_chips]` band.

use hyca::coordinator::{exp_traffic, RunOpts};
use hyca::fleet::{self, FleetEventKind};
use hyca::inference::Engine;
use std::sync::Arc;

const SEED: u64 = 0xC0FFEE;

fn opts(seed: u64, threads: usize) -> RunOpts {
    RunOpts {
        seed,
        threads,
        out_dir: std::env::temp_dir().join("hyca_traffic_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

#[test]
fn bench_json_is_byte_identical_at_any_worker_count() {
    let narrow = exp_traffic::bench_json(&opts(SEED, 1), true).unwrap();
    let wide = exp_traffic::bench_json(&opts(SEED, 8), true).unwrap();
    assert_eq!(narrow, wide, "worker count leaked into the traffic metrics");
    let again = exp_traffic::bench_json(&opts(SEED, 1), true).unwrap();
    assert_eq!(narrow, again);
    // and the seed actually matters: a different arrival stream
    let other = exp_traffic::bench_json(&opts(0xBEEF, 1), true).unwrap();
    assert_ne!(narrow, other);
}

#[test]
fn bench_json_has_the_documented_schema() {
    let json = exp_traffic::bench_json(&opts(SEED, 2), true).unwrap();
    for key in [
        "\"schema\": \"hyca-traffic-bench-v3\"",
        "\"scenarios\": [",
        "\"scenario\": \"open_steady\"",
        "\"scenario\": \"flash_crowd\"",
        "\"scenario\": \"open_diurnal\"",
        "\"offered\":",
        "\"admitted\":",
        "\"shed_rate\":",
        "\"goodput_imgs_per_mcycle\":",
        "\"slo_attainment\":",
        "\"active_chips\": [[0, ",
        "\"spec_hash\":",
        // the PR 7 windowed section: per-window series collected from
        // the deterministic trace stream, one entry per preset
        "\"timeseries\": [",
        "\"window_cycles\":",
        "\"queue_depth\":",
        "\"in_flight\":",
        "\"enqueued\":",
        "\"completed\":",
        "\"live_faults\":",
        "\"per_chip_completed\":",
        // v3: the per-chip lane-occupancy series (the collector gauge
        // `repro audit` prices utilization from)
        "\"per_chip_busy_lane_cycles\":",
    ] {
        assert!(json.contains(key), "missing {key} in:\n{json}");
    }
    // everything is simulated time — wall-clock fields are forbidden
    for forbidden in ["seconds", "wall", "ns_per"] {
        assert!(!json.contains(forbidden), "wall-clock field {forbidden:?}");
    }
}

#[test]
fn open_steady_degenerates_to_the_closed_loop_contract() {
    // one chip at ~27% utilisation: the admission controller never
    // fires and every offered request completes correctly — open mode
    // at low rate is behaviourally the closed loop
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_traffic::traffic_config("open_steady", SEED, true, 2);
    assert_eq!(cfg.chips.len(), 1);
    assert!(cfg.admission.is_some(), "open_steady carries its SLO");
    let report = fleet::run(&engine, &cfg).unwrap();
    assert!(report.offered > 0, "the horizon must produce arrivals");
    assert_eq!(report.shed, 0, "under-load must never shed");
    assert_eq!(report.total_requests, report.offered);
    assert_eq!(report.accuracy, 1.0, "admitted work is never degraded");
    assert_eq!(report.slo_attainment, Some(1.0), "under-load meets the SLO");
    assert_eq!(report.active_chips, vec![(0, 1)], "no autoscaler, no steps");
}

#[test]
fn flash_crowd_sheds_under_overload_without_degrading_admitted_work() {
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_traffic::traffic_config("flash_crowd", SEED, true, 2);
    let report = fleet::run(&engine, &cfg).unwrap();
    // the spike is ~5× fleet capacity: shedding is load-bearing
    assert!(report.shed > 0, "flash crowd must shed");
    assert_eq!(report.total_requests + report.shed, report.offered);
    assert!(report.total_requests > 0, "base load must be admitted");
    assert!(report.shed_rate() > 0.0 && report.shed_rate() < 1.0);
    // the whole point of admission control: what gets in, gets served
    // correctly and (overwhelmingly) on time
    assert_eq!(report.accuracy, 1.0, "admitted work is never degraded");
    let att = report.slo_attainment.expect("SLO configured");
    assert!(
        att >= 0.8,
        "admitted requests must overwhelmingly meet the 60k-cycle SLO \
         (attainment {att:.4})"
    );
}

#[test]
fn autoscaler_tracks_the_spike_and_never_flaps() {
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_traffic::traffic_config("flash_crowd", SEED, true, 2);
    let auto = cfg.autoscale.expect("flash_crowd autoscales");
    let report = fleet::run(&engine, &cfg).unwrap();
    // trajectory starts at min_chips and grows under the spike
    assert_eq!(report.active_chips[0], (0, auto.min_chips));
    let scales: Vec<_> = report
        .events
        .iter()
        .filter(|e| {
            matches!(e.kind, FleetEventKind::ScaledUp | FleetEventKind::ScaledDown)
        })
        .collect();
    assert!(
        scales.iter().any(|e| e.kind == FleetEventKind::ScaledUp),
        "the spike must trigger a scale-up"
    );
    // flap guard: consecutive decisions are at least a dwell apart
    for pair in scales.windows(2) {
        assert!(
            pair[1].cycle - pair[0].cycle >= auto.dwell_cycles,
            "scale events at {} and {} violate the {}-cycle dwell",
            pair[0].cycle,
            pair[1].cycle,
            auto.dwell_cycles
        );
    }
    // the trajectory never leaves the configured band
    for &(_, n) in &report.active_chips {
        assert!(
            (auto.min_chips..=auto.max_chips).contains(&n),
            "active count {n} outside [{}, {}]",
            auto.min_chips,
            auto.max_chips
        );
    }
}

#[test]
fn windowed_active_chips_expose_the_flash_crowd_ramp() {
    // the satellite fix for the autoscale-tick sampling artefact: the
    // legacy `active_chips` trajectory only records decision points,
    // while the windowed series samples the gauge at every window edge
    // — so the ramp is visible even between autoscale ticks, and the
    // two views agree at the endpoints
    use hyca::obs::{timeseries, MemorySink};
    let engine = Arc::new(Engine::builtin());
    let cfg = exp_traffic::traffic_config("flash_crowd", SEED, true, 2);
    let mut sink = MemorySink::default();
    let report = fleet::run_traced(&engine, &cfg, &mut sink).unwrap();
    let series = timeseries::collect(
        &sink.events,
        report.total_cycles,
        timeseries::DEFAULT_WINDOWS,
        report.chips,
        report.active_chips[0].1,
    );
    assert_eq!(series.windows.len(), timeseries::DEFAULT_WINDOWS);
    let active: Vec<usize> = series.windows.iter().map(|w| w.active_chips).collect();
    assert!(
        active.iter().max() > active.iter().min(),
        "the spike must move the windowed active-chip gauge: {active:?}"
    );
    assert_eq!(
        *active.last().unwrap(),
        report.active_chips.last().unwrap().1,
        "the final window must agree with the legacy trajectory"
    );
    // conservation: the windowed counters partition the run's totals
    let completed: u64 = series.windows.iter().map(|w| w.completed).sum();
    assert_eq!(completed as usize, report.total_requests);
    let shed: u64 = series.windows.iter().map(|w| w.shed).sum();
    assert_eq!(shed as usize, report.shed);
}

#[test]
fn open_arrival_streams_replay_and_scale_with_the_rate() {
    use hyca::serve::loadgen::{open_arrivals, RateCurve, OPEN_ARRIVAL_STREAM};
    let curve = RateCurve::Constant { per_kcycle: 2.0 };
    let a = open_arrivals(SEED, OPEN_ARRIVAL_STREAM, &curve, 100_000, 64, 4_096);
    let b = open_arrivals(SEED, OPEN_ARRIVAL_STREAM, &curve, 100_000, 64, 4_096);
    assert_eq!(a, b, "arrival stream must replay from its seed");
    assert!(!a.is_empty());
    assert!(a.windows(2).all(|w| w[0].cycle <= w[1].cycle), "arrivals sorted");
    assert!(a.iter().all(|x| x.cycle < 100_000 && x.image_idx < 64));
    // doubling the rate roughly doubles the arrivals (Poisson means:
    // 200 vs 400 — the 3σ bands don't overlap)
    let double =
        open_arrivals(SEED, OPEN_ARRIVAL_STREAM, &curve.scaled(2.0), 100_000, 64, 4_096);
    assert!(
        double.len() > a.len() + a.len() / 2,
        "rate scaling is dead: {} vs {}",
        double.len(),
        a.len()
    );
}
