//! Fleet-subsystem acceptance tests.
//!
//! 1. **Golden determinism**: the `BENCH_fleet.json` metrics are a
//!    pure function of the master seed — byte-identical at any
//!    `--workers` (executor thread) value and across repeated runs.
//! 2. **Degeneracy**: a 1-chip fleet under round-robin routing
//!    reproduces `serve` exactly — per-request predictions and the
//!    full cycle timeline (see also the property test in
//!    `rust/tests/proptests.rs`, which sweeps random configurations).
//! 3. **Drain scenario**: a chip crossing the live-fault threshold is
//!    drained out of the serving set, repaired by its scan agent,
//!    re-admitted — and the fleet serves every request with accuracy
//!    returning to exactly 1.0. Which seed shows the full story
//!    depends on where the faults land, so the test scans a handful of
//!    seeds for observability (never for the outcome) exactly like the
//!    serve scenario test.

use hyca::coordinator::{exp_fleet, exp_serve, RunOpts};
use hyca::fleet::{self, FleetConfig, FleetEventKind, RoutingPolicy};
use hyca::inference::Engine;
use hyca::serve;
use std::sync::Arc;

fn opts(seed: u64, threads: usize) -> RunOpts {
    RunOpts {
        seed,
        threads,
        out_dir: std::env::temp_dir().join("hyca_fleet_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

#[test]
fn bench_json_is_byte_identical_at_any_executor_width() {
    let narrow = exp_fleet::bench_json(&opts(0xC0FFEE, 1), true).unwrap();
    let wide = exp_fleet::bench_json(&opts(0xC0FFEE, 4), true).unwrap();
    assert_eq!(
        narrow, wide,
        "executor width leaked into the fleet metrics"
    );
    // repeat run: byte-identical again
    let again = exp_fleet::bench_json(&opts(0xC0FFEE, 1), true).unwrap();
    assert_eq!(narrow, again);
    // and the seed actually matters
    let other = exp_fleet::bench_json(&opts(0xBEEF, 1), true).unwrap();
    assert_ne!(narrow, other);
}

#[test]
fn bench_json_has_the_documented_schema() {
    let json = exp_fleet::bench_json(&opts(0xC0FFEE, 2), true).unwrap();
    for key in [
        "\"schema\": \"hyca-fleet-bench-v2\"",
        "\"grid\": [",
        "\"chips\": 1",
        "\"chips\": 4",
        "\"policy\": \"round_robin\"",
        "\"policy\": \"jsq\"",
        "\"policy\": \"health_weighted\"",
        "\"throughput_imgs_per_mcycle\":",
        "\"p50_cycles\":",
        "\"p99_cycles\":",
        "\"accuracy\":",
        "\"mixed_fleet\": [",
        "\"topology\": \"3*8x8\"",
        "\"load_imbalance\":",
    ] {
        assert!(json.contains(key), "missing {key} in:\n{json}");
    }
    // no wall-clock fields, ever
    for forbidden in ["seconds", "wall", "ns_per"] {
        assert!(!json.contains(forbidden), "wall-clock field {forbidden:?}");
    }
}

#[test]
fn one_chip_fleet_matches_serve_predictions_and_timeline() {
    // the degeneracy acceptance criterion, end to end on the exact
    // serve scenario configuration (mid-run faults included)
    let engine = Arc::new(Engine::builtin());
    let serve_cfg = exp_serve::scenario_config(0xC0FFEE, true, 2);
    let serve_report = serve::run(&engine, &serve_cfg).unwrap();
    let fleet_report = fleet::run(&engine, &FleetConfig::degenerate(&serve_cfg)).unwrap();
    assert_eq!(fleet_report.predictions, serve_report.predictions);
    assert_eq!(fleet_report.correct, serve_report.correct);
    assert_eq!(fleet_report.accuracy, serve_report.accuracy);
    assert_eq!(fleet_report.total_cycles, serve_report.total_cycles);
    assert_eq!(fleet_report.batches, serve_report.batches);
    assert_eq!(fleet_report.max_pending, serve_report.max_pending);
    assert_eq!(fleet_report.unrepaired, serve_report.unrepaired);
    assert_eq!(
        fleet_report.latency_cycles, serve_report.latency_cycles,
        "the 1-chip cluster histogram is serve's histogram"
    );
    // window accounting agrees (same cycle timeline, same windowing)
    assert_eq!(fleet_report.windows.len(), serve_report.windows.len());
    for (fw, sw) in fleet_report.windows.iter().zip(&serve_report.windows) {
        assert_eq!((fw.start_cycle, fw.end_cycle), (sw.start_cycle, sw.end_cycle));
        assert_eq!((fw.requests, fw.correct), (sw.requests, sw.correct));
    }
}

#[test]
fn scenario_report_is_invariant_to_executor_width() {
    let a = exp_fleet::scenario_report(&opts(0xC0FFEE, 1), true).unwrap();
    let b = exp_fleet::scenario_report(&opts(0xC0FFEE, 5), true).unwrap();
    assert_eq!(a.digest(), b.digest());
}

#[test]
fn drain_scenario_drains_repairs_readmits_and_recovers_exactly() {
    // Find a seed whose fault draw tells the whole story: a chip
    // crosses the threshold (drain + later re-admission), at least one
    // prediction visibly flips, every fault is repaired, and the last
    // detection lands early enough that recovery is temporally possible
    // within the run. Given such a seed, exact recovery and zero drops
    // are *structural* properties the assertions verify — the search
    // only selects observability, never the outcome.
    let mut hit = None;
    for seed in 0..48u64 {
        let report = exp_fleet::scenario_report(&opts(seed, 2), true).unwrap();
        let drained = report
            .events
            .iter()
            .any(|e| e.kind == FleetEventKind::Drained);
        let readmitted = report
            .events
            .iter()
            .any(|e| e.kind == FleetEventKind::Readmitted);
        let dipped = report
            .windows
            .iter()
            .any(|w| w.accuracy().map(|a| a < 1.0).unwrap_or(false));
        let window_len = report.windows[0].end_cycle - report.windows[0].start_cycle;
        let timely = report
            .events
            .iter()
            .filter(|e| matches!(e.kind, FleetEventKind::ScanDetection(_)))
            .map(|e| e.cycle)
            .max()
            .map(|last| last + 3 * window_len <= report.total_cycles)
            .unwrap_or(false);
        if drained && readmitted && dipped && report.unrepaired == 0 && timely {
            hit = Some((seed, report));
            break;
        }
    }
    let (seed, report) = hit.expect(
        "no seed in 0..48 produced a drained+readmitted chip with a visible, \
         timely-repaired dip — scenario broken",
    );

    // zero dropped requests: the closed loop served its whole budget
    assert_eq!(report.total_requests, report.predictions.len());
    assert_eq!(report.latency_cycles.count() as usize, report.total_requests);
    let per_chip: usize = report.per_chip.iter().map(|c| c.requests).sum();
    assert_eq!(per_chip, report.total_requests, "seed {seed}: requests lost");

    // lifecycle story, in order: some chip's drain precedes its
    // re-admission, and a detection lands in between (repair while out
    // of service)
    let drain = report
        .events
        .iter()
        .find(|e| e.kind == FleetEventKind::Drained)
        .unwrap();
    let readmit = report
        .events
        .iter()
        .find(|e| e.chip == drain.chip && e.kind == FleetEventKind::Readmitted)
        .expect("the drained chip must be re-admitted");
    assert!(drain.cycle < readmit.cycle);
    assert!(
        report.events.iter().any(|e| e.chip == drain.chip
            && matches!(e.kind, FleetEventKind::ScanDetection(_))
            && e.cycle > drain.cycle
            && e.cycle <= readmit.cycle),
        "seed {seed}: re-admission must follow a scan repair"
    );
    // the drained chip shows up in the availability accounting
    assert!(report.availability() < 1.0, "seed {seed}");
    assert!(report.per_chip[drain.chip].drains >= 1);

    // every fault repaired, and accuracy returns to exactly 1.0
    assert_eq!(report.unrepaired, 0, "seed {seed}");
    assert_eq!(
        report.final_window_accuracy(),
        Some(1.0),
        "seed {seed}: fleet accuracy did not recover to exactly 1.0"
    );
    // the disturbance is real but bounded
    assert!(report.accuracy < 1.0);
    assert!(report.accuracy > 0.25, "seed {seed}: dip, not outage");
}

#[test]
fn fleet_experiment_tables_render() {
    let (tables, json) = exp_fleet::run_full(&opts(0xC0FFEE, 2), true, None).unwrap();
    assert_eq!(tables.len(), 5);
    let grid = tables[0].to_markdown();
    assert!(grid.contains("imgs_per_Mcycle") && grid.contains("policy"));
    let mixed = tables[1].to_markdown();
    assert!(mixed.contains("load_imbalance") && mixed.contains("topology"));
    assert!(mixed.contains("8x8+16x16+32x32"));
    let timeline = tables[2].to_markdown();
    assert!(timeline.contains("availability") && timeline.contains("goodput"));
    let chips = tables[3].to_markdown();
    assert!(chips.contains("drained_kcycles"));
    let summary = tables[4].to_markdown();
    assert!(summary.contains("recovered_exactly") && summary.contains("drain_episodes"));
    assert!(json.starts_with("{\n"));
}

#[test]
fn chips_override_restricts_the_grid() {
    let (tables, json) = exp_fleet::run_full(&opts(0xC0FFEE, 2), true, Some(2)).unwrap();
    let grid = tables[0].to_markdown();
    assert!(json.contains("\"chips\": 2"));
    assert!(!json.contains("\"chips\": 1") && !json.contains("\"chips\": 4"));
    assert!(grid.contains("round_robin") && grid.contains("health_weighted"));
}

#[test]
fn routing_policies_agree_on_totals_but_not_necessarily_on_latency() {
    // same cluster, same load, three policies: every request served
    // under each, perfect accuracy when fault-free
    let engine = Arc::new(Engine::builtin());
    for policy in RoutingPolicy::all() {
        let cfg = exp_fleet::fleet_cell(7, 4, policy, true, 2);
        let report = fleet::run(&engine, &cfg).unwrap();
        assert_eq!(report.total_requests, cfg.total_requests, "{policy}");
        assert_eq!(report.accuracy, 1.0, "{policy}");
        assert_eq!(report.availability(), 1.0, "{policy}");
    }
}
