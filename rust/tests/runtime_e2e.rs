//! End-to-end tests over the inference backends.
//!
//! The **native** backend is exercised unconditionally on the builtin
//! synthetic model (hermetic: no artifacts, no native libraries): it
//! must agree bit-for-bit with the rust functional oracle, and the full
//! fault→accuracy→repair story must hold exactly.
//!
//! The **PJRT** path is exercised only under `--features pjrt`; those
//! tests additionally need `make artifacts` and are skipped (with a
//! loud message) if the artifacts are missing so `cargo test` stays
//! green on a fresh checkout.

use hyca::array::Dims;
use hyca::faults::montecarlo::FaultModel;
use hyca::faults::FaultConfig;
use hyca::inference::{oracle_logits, Engine, LayerMasks};
use hyca::runtime::I32Tensor;

/// Feed one batch straight through the backend (bypassing the argmax)
/// and return the raw logits tensor.
fn backend_logits(engine: &Engine, images: &[Vec<i8>], masks: &LayerMasks) -> I32Tensor {
    engine.logits(images, masks).unwrap()
}

#[test]
fn native_backend_matches_rust_oracle_bit_exactly() {
    let engine = Engine::builtin();
    let geometry = engine.geometry();
    // A mix of healthy and corrupted runs, deterministic seeds.
    for (seed, n_faults) in [(1u64, 0usize), (2, 1), (3, 7), (4, 40)] {
        let dims = Dims::PAPER;
        let cfg = if n_faults == 0 {
            FaultConfig::healthy(dims)
        } else {
            let mut rng = hyca::util::rng::Pcg32::new(seed, 99);
            hyca::faults::random::sample_exact(&mut rng, dims, n_faults)
        };
        let masks = LayerMasks::from_faults(&geometry, &cfg, &|_, _| false, 1e-4, seed);
        let images = &engine.eval.images[..engine.batch];
        let logits = backend_logits(&engine, images, &masks);
        assert_eq!(logits.shape, vec![engine.batch, 10]);
        for (b, img) in images.iter().enumerate() {
            let want = oracle_logits(&engine.params, img, &masks);
            let got = &logits.data[b * 10..(b + 1) * 10];
            assert_eq!(
                got, &want[..],
                "logits mismatch seed={seed} faults={n_faults} batch_row={b}"
            );
        }
    }
}

#[test]
fn builtin_clean_accuracy_is_exactly_one() {
    let engine = Engine::builtin();
    let geometry = engine.geometry();
    let acc = engine.accuracy(&LayerMasks::identity(&geometry)).unwrap();
    // labels are the clean model's own argmax, so this is exact
    assert_eq!(acc, 1.0);
}

#[test]
fn fault_injection_degrades_and_full_repair_restores() {
    let engine = Engine::builtin();
    let geometry = engine.geometry();
    // the functional experiment maps the CNN onto an 8×8 array (see
    // exp_fig02.rs header for the ratio argument)
    let dims = Dims::new(8, 8);
    let clean = engine.accuracy(&LayerMasks::identity(&geometry)).unwrap();
    // Scan deterministic configurations at 6% PER until one degrades
    // accuracy (fault impact varies a lot per config — that variance is
    // itself the paper's Fig. 2 observation).
    let mut hit = None;
    for i in 0..32u64 {
        let cfg = FaultModel::Random.sample_indexed(0xE2E, i, dims, 0.06);
        if cfg.count() == 0 || cfg.count() > 8 {
            continue; // need repairable-within-capacity loads
        }
        let faulty = LayerMasks::from_faults(&geometry, &cfg, &|_, _| false, 1e-4, i);
        let acc_faulty = engine.accuracy(&faulty).unwrap();
        if acc_faulty < clean {
            hit = Some((cfg, acc_faulty, i));
            break;
        }
    }
    let (cfg, acc_faulty, seed) =
        hit.expect("no configuration among 32 degraded accuracy at all");
    assert!(acc_faulty < clean);
    // HyCA repairs everything within capacity → accuracy fully restored
    let repaired = LayerMasks::from_faults(&geometry, &cfg, &|_, _| true, 1e-4, seed);
    let acc_rep = engine.accuracy(&repaired).unwrap();
    assert_eq!(
        acc_rep, clean,
        "full repair must restore exact clean accuracy"
    );
}

#[test]
fn batch_size_contract_enforced() {
    let engine = Engine::builtin();
    let geometry = engine.geometry();
    let masks = LayerMasks::identity(&geometry);
    let too_few = &engine.eval.images[..engine.batch - 1];
    assert!(engine.predict_batch(too_few, &masks).is_err());
}

/// Artifact-path coverage on *any* build: when artifacts exist,
/// `Engine::load` (PJRT backend under the feature, native over the
/// parsed weights otherwise) must reproduce the python-side quantized
/// eval accuracy recorded in the manifest. Skipped without artifacts.
#[test]
fn artifact_accuracy_matches_manifest_when_present() {
    let engine = match Engine::load() {
        Ok(e) => e,
        Err(err) => {
            eprintln!("SKIPPING artifact accuracy test (run `make artifacts`): {err}");
            return;
        }
    };
    let geometry = engine.geometry();
    let acc = engine.accuracy(&LayerMasks::identity(&geometry)).unwrap();
    let dir = hyca::runtime::artifacts_dir().unwrap();
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    let recorded: f64 = manifest
        .lines()
        .find_map(|l| l.strip_prefix("quant_eval_acc "))
        .unwrap()
        .parse()
        .unwrap();
    assert!(
        (acc - recorded).abs() < 0.02,
        "rust-side accuracy {acc} vs python-side {recorded}"
    );
    assert!(acc > 0.9, "healthy accuracy should be high: {acc}");
}

#[test]
fn auto_engine_always_constructs() {
    // On a checkout without artifacts this is the builtin fallback; with
    // artifacts it is the artifact engine. Either way it must serve.
    let engine = Engine::auto();
    let geometry = engine.geometry();
    let acc = engine.accuracy(&LayerMasks::identity(&geometry)).unwrap();
    assert!(
        (0.0..=1.0).contains(&acc),
        "accuracy out of range: {acc}"
    );
    assert!(!engine.backend.name().is_empty());
}

/// PJRT-dependent tests: compiled HLO vs the same oracle. Only built
/// under `--features pjrt`; skipped at runtime without artifacts.
#[cfg(feature = "pjrt")]
mod pjrt_e2e {
    use super::*;
    use hyca::runtime::artifacts_dir;
    use hyca::runtime::pjrt::Runtime;

    fn engine_or_skip() -> Option<Engine> {
        match Engine::load() {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("SKIPPING pjrt e2e test (run `make artifacts`): {err}");
                None
            }
        }
    }

    #[test]
    fn hlo_model_matches_rust_oracle_bit_exactly() {
        let Some(engine) = engine_or_skip() else { return };
        let geometry = engine.geometry();
        for (seed, n_faults) in [(1u64, 0usize), (2, 1), (3, 7), (4, 40)] {
            let dims = Dims::PAPER;
            let cfg = if n_faults == 0 {
                FaultConfig::healthy(dims)
            } else {
                let mut rng = hyca::util::rng::Pcg32::new(seed, 99);
                hyca::faults::random::sample_exact(&mut rng, dims, n_faults)
            };
            let masks =
                LayerMasks::from_faults(&geometry, &cfg, &|_, _| false, 1e-4, seed);
            let images = &engine.eval.images[..engine.batch];
            let logits = backend_logits(&engine, images, &masks);
            for (b, img) in images.iter().enumerate() {
                let want = oracle_logits(&engine.params, img, &masks);
                let got = &logits.data[b * 10..(b + 1) * 10];
                assert_eq!(
                    got, &want[..],
                    "logits mismatch seed={seed} faults={n_faults} batch_row={b}"
                );
            }
        }
    }

    // (the manifest-accuracy check lives in the outer module — it needs
    // artifacts but not PJRT, so it runs on default builds too)

    #[test]
    fn standalone_kernel_artifact_matches_oracle() {
        let Some(_) = engine_or_skip() else { return };
        let dir = artifacts_dir().unwrap();
        let rt = Runtime::cpu().unwrap();
        let kernel = rt.load_hlo(dir.join("kernel_faulty_matmul.hlo.txt")).unwrap();
        let (m, k, n) = (256usize, 128usize, 64usize);
        let mut rng = hyca::util::rng::Pcg32::new(0xBEEF, 0);
        let x: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32 - 128).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32 - 128).collect();
        let bias: Vec<i32> = (0..n).map(|_| rng.below(2000) as i32 - 1000).collect();
        let mut am = vec![-1i32; m * n];
        let mut om = vec![0i32; m * n];
        am[5 * n + 3] = !(1 << 30);
        om[7 * n + 1] = 1 << 6;
        let out = kernel
            .execute_i32(&[
                I32Tensor::new(vec![m, k], x.clone()),
                I32Tensor::new(vec![k, n], w.clone()),
                I32Tensor::new(vec![m, n], am.clone()),
                I32Tensor::new(vec![m, n], om.clone()),
                I32Tensor::new(vec![n], bias.clone()),
            ])
            .unwrap();
        assert_eq!(out.shape, vec![m, n]);
        // rust oracle
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias[j];
                for t in 0..k {
                    acc = acc
                        .wrapping_add((x[i * k + t] as i8 as i32) * (w[t * n + j] as i8 as i32));
                }
                let want = ((acc as u32 & am[i * n + j] as u32) | om[i * n + j] as u32) as i32;
                assert_eq!(out.data[i * n + j], want, "({i},{j})");
            }
        }
    }
}
