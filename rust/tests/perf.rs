//! Perf-harness acceptance tests.
//!
//! The wall-clock `timing` section of `BENCH_perf.json` is
//! nondeterministic **by design** (and marked so in the schema), so
//! these tests pin everything around it: the deterministic workload
//! section replays byte-identically, the JSON is well-formed with the
//! documented keys, and the timed grid's internal bit-exactness
//! assertion (every cell == the 1-thread shared-queue reference)
//! actually runs — `run_perf` returning `Ok` *is* that proof, because
//! divergence is an error, not a statistic.

use hyca::coordinator::{exp_perf, find, RunOpts};

fn opts(seed: u64) -> RunOpts {
    RunOpts {
        seed,
        threads: 2,
        out_dir: std::env::temp_dir().join("hyca_perf_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

#[test]
fn deterministic_section_is_byte_identical_across_runs() {
    let a = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    let b = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    assert_eq!(a.det, b.det, "workload descriptions must replay exactly");
    assert_eq!(
        exp_perf::det_json(0xC0FFEE, true, &a.det),
        exp_perf::det_json(0xC0FFEE, true, &b.det)
    );
    // and the seed actually matters
    let c = exp_perf::run_perf(&opts(0xBEEF), true, 1).unwrap();
    assert_ne!(a.det, c.det);
}

#[test]
fn bench_json_has_the_documented_schema_and_marks_timing_nondeterministic() {
    let run = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    let json = exp_perf::perf_json(0xC0FFEE, true, &run);
    for key in [
        "\"schema\": \"hyca-perf-bench-v1\"",
        "\"deterministic\": {",
        "\"grid\": [",
        "\"chips\": 1",
        "\"chips\": 4",
        "\"total_cycles\":",
        "\"timing\": {",
        "\"nondeterministic\": true",
        "\"executor\": \"shared\"",
        "\"executor\": \"steal_off\"",
        "\"executor\": \"steal_on\"",
        "\"wall_ms\":",
        "\"jobs_per_sec\":",
        "\"steals\":",
    ] {
        assert!(json.contains(key), "missing {key} in:\n{json}");
    }
    assert!(json.starts_with("{\n") && json.ends_with("}\n"));
}

#[test]
fn timing_grid_covers_every_cell_and_shared_never_steals() {
    let run = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    let chips = exp_perf::chip_sweep(true);
    assert_eq!(
        run.timing.len(),
        chips.len() * exp_perf::THREAD_SWEEP.len() * exp_perf::mode_sweep().len(),
        "one timed row per (chips × threads × executor) cell"
    );
    for row in &run.timing {
        assert!(row.wall_ms > 0.0, "{row:?}");
        assert!(row.jobs_per_sec > 0.0, "{row:?}");
        if row.executor != "steal_on" {
            assert_eq!(row.steals, 0, "only steal_on may steal: {row:?}");
        }
        if row.threads == 1 {
            assert_eq!(row.steals, 0, "a lone worker cannot steal: {row:?}");
        }
    }
    // the deterministic section names every swept chip count
    let det_chips: Vec<usize> = run.det.iter().map(|d| d.chips).collect();
    assert_eq!(det_chips, chips);
}

#[test]
fn perf_experiment_is_registered_and_renders_tables() {
    let exp = find("perf").expect("perf must be in the registry");
    let tables = exp
        .run(&RunOpts { fast: true, ..opts(0xC0FFEE) })
        .unwrap();
    assert_eq!(tables.len(), 2);
    let workloads = tables[0].to_markdown();
    assert!(workloads.contains("total_cycles"));
    let grid = tables[1].to_markdown();
    assert!(grid.contains("speedup_vs_shared") && grid.contains("steal_on"));
}
