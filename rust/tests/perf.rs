//! Perf-harness acceptance tests.
//!
//! The wall-clock `timing` section of `BENCH_perf.json` is
//! nondeterministic **by design** (and marked so in the schema), so
//! these tests pin everything around it: the deterministic workload
//! section replays byte-identically, the JSON is well-formed with the
//! documented keys, and the timed grid's internal bit-exactness
//! assertion (every cell == the 1-thread shared-queue reference)
//! actually runs — `run_perf` returning `Ok` *is* that proof, because
//! divergence is an error, not a statistic.

use hyca::coordinator::{exp_perf, find, RunOpts};

fn opts(seed: u64) -> RunOpts {
    RunOpts {
        seed,
        threads: 2,
        out_dir: std::env::temp_dir().join("hyca_perf_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

#[test]
fn deterministic_section_is_byte_identical_across_runs() {
    let a = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    let b = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    assert_eq!(a.det, b.det, "workload descriptions must replay exactly");
    assert_eq!(
        exp_perf::det_json(0xC0FFEE, true, &a.det),
        exp_perf::det_json(0xC0FFEE, true, &b.det)
    );
    // and the seed actually matters
    let c = exp_perf::run_perf(&opts(0xBEEF), true, 1).unwrap();
    assert_ne!(a.det, c.det);
}

#[test]
fn bench_json_has_the_documented_schema_and_marks_timing_nondeterministic() {
    let run = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    let json = exp_perf::perf_json(0xC0FFEE, true, &run);
    for key in [
        "\"schema\": \"hyca-perf-bench-v2\"",
        "\"deterministic\": {",
        "\"grid\": [",
        "\"chips\": 1",
        "\"chips\": 4",
        "\"total_cycles\":",
        "\"timing\": {",
        "\"nondeterministic\": true",
        "\"executor\": \"shared\"",
        "\"executor\": \"steal_off\"",
        "\"executor\": \"mutex\"",
        "\"executor\": \"lockfree\"",
        "\"home_set\": 2",
        "\"wall_ms\":",
        "\"jobs_per_sec\":",
        "\"steals\":",
    ] {
        assert!(json.contains(key), "missing {key} in:\n{json}");
    }
    assert!(json.starts_with("{\n") && json.ends_with("}\n"));
}

#[test]
fn timing_grid_covers_every_cell_and_only_stealing_plans_steal() {
    let run = exp_perf::run_perf(&opts(0xC0FFEE), true, 1).unwrap();
    let chips = exp_perf::chip_sweep(true);
    assert_eq!(
        run.timing.len(),
        chips.len() * exp_perf::THREAD_SWEEP.len() * exp_perf::plan_sweep().len(),
        "one timed row per (chips × threads × plan) cell"
    );
    for row in &run.timing {
        assert!(row.wall_ms > 0.0, "{row:?}");
        assert!(row.jobs_per_sec > 0.0, "{row:?}");
        if row.executor != "mutex" && row.executor != "lockfree" {
            assert_eq!(row.steals, 0, "only stealing plans may steal: {row:?}");
        }
        if row.threads == 1 {
            assert_eq!(row.steals, 0, "a lone worker cannot steal: {row:?}");
        }
        assert!(row.home_set >= 1, "{row:?}");
    }
    // both deques are measured head-to-head at every (chips, threads)
    for &c in &chips {
        for &t in &exp_perf::THREAD_SWEEP {
            for exec in ["shared", "steal_off", "mutex", "lockfree"] {
                assert!(
                    run.timing
                        .iter()
                        .any(|r| r.chips == c && r.threads == t && r.executor == exec),
                    "missing {exec} row at chips={c} threads={t}"
                );
            }
            // the home-set satellite row rides on the lock-free deque
            assert!(
                run.timing.iter().any(|r| r.chips == c
                    && r.threads == t
                    && r.executor == "lockfree"
                    && r.home_set == 2),
                "missing lockfree home_set=2 row at chips={c} threads={t}"
            );
        }
    }
    // the deterministic section names every swept chip count
    let det_chips: Vec<usize> = run.det.iter().map(|d| d.chips).collect();
    assert_eq!(det_chips, chips);
}

#[test]
fn perf_experiment_is_registered_and_renders_tables() {
    let exp = find("perf").expect("perf must be in the registry");
    let tables = exp
        .run(&RunOpts { fast: true, ..opts(0xC0FFEE) })
        .unwrap();
    assert_eq!(tables.len(), 2);
    let workloads = tables[0].to_markdown();
    assert!(workloads.contains("total_cycles"));
    let grid = tables[1].to_markdown();
    assert!(grid.contains("speedup_vs_shared"));
    assert!(grid.contains("mutex") && grid.contains("lockfree"));
}
