//! Acceptance tests for the attribution ledger and the bench auditor
//! (DESIGN.md §11).
//!
//! 1. **Exact-sum invariant**: for every completed request of a random
//!    fleet run, `admission + batch + queue + fault + execution ==
//!    end-to-end` — cycles, not approximations.
//! 2. **Worker invariance**: the rendered ledger and `BENCH_audit.json`
//!    are byte-identical at any executor width.
//! 3. **Episode boundaries**: synthetic streams pin the window
//!    semantics — drain extension to re-admit, unresolved drains,
//!    unrepaired faults, the closing remap being priced.
//! 4. **Diff gate**: identical inputs pass, seeded regressions fail
//!    with a nonzero count, tolerances and severity classes behave as
//!    documented in EXPERIMENTS.md.

use hyca::array::Dims;
use hyca::coordinator::{exp_audit, RunOpts};
use hyca::fleet::{self, ChipSpec, FaultPlan, FleetConfig, LifecyclePolicy, RoutingPolicy};
use hyca::inference::Engine;
use hyca::obs::attrib::{render_ledger, SpanLedger};
use hyca::obs::{audit, TraceEvent as E};
use hyca::testkit::{check, Gen};
use std::sync::Arc;

const SEED: u64 = 0xC0FFEE;

fn opts(seed: u64, threads: usize) -> RunOpts {
    RunOpts {
        seed,
        threads,
        out_dir: std::env::temp_dir().join("hyca_audit_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

// ---------------------------------------------------------------- ledger

fn random_fleet_cfg(g: &mut Gen) -> FleetConfig {
    let n_chips = g.usize_in(1, 4);
    let clients = g.usize_in(1, 3) * n_chips;
    let faults = if g.bool(0.5) {
        Some(FaultPlan {
            mean_interarrival_cycles: 20_000.0,
            horizon_cycles: 60_000,
            scan_period_cycles: 4_000,
            group_width: 8,
            fpt_capacity: 8,
            max_arrivals: g.usize_in(1, 4),
            spatial: hyca::faults::Spatial::Random,
        })
    } else {
        None
    };
    FleetConfig {
        seed: g.usize_in(0, 1 << 20) as u64,
        chips: vec![ChipSpec { dims: Dims::new(8, 8), lanes: g.usize_in(1, 3) }; n_chips],
        policy: *g.choose(&RoutingPolicy::all()),
        max_batch: g.usize_in(1, 5),
        max_wait_cycles: g.usize_in(0, 10_000) as u64,
        clients,
        think_cycles: g.usize_in(0, 1_000) as u64,
        total_requests: g.usize_in(4, 8 * n_chips),
        queue_cap: clients,
        executor_threads: 1,
        home_set: g.usize_in(1, 3),
        windows: 4,
        faults,
        lifecycle: LifecyclePolicy::NEVER,
        open_loop: None,
        admission: None,
        autoscale: None,
    }
}

#[test]
fn prop_ledger_sums_exactly_and_is_worker_invariant() {
    // The attribution contract on random fleets: the five components
    // sum to end-to-end on every span, every admitted request closes a
    // span, and the rendered ledger is a pure function of the seed —
    // byte-identical at any executor width.
    check("ledger exact sums + worker invariance", 6, |g| {
        let engine = Arc::new(Engine::builtin());
        let cfg = random_fleet_cfg(g);
        let run = |threads: usize| {
            let mut c = cfg.clone();
            c.executor_threads = threads;
            let mut ledger = SpanLedger::new(&c.lane_counts());
            let report = fleet::run_traced(&engine, &c, &mut ledger).unwrap();
            (ledger.finish(report.total_cycles, &report.correct), report)
        };
        let (audit, report) = run(1);
        assert_eq!(
            audit.spans.len(),
            report.total_requests,
            "every admitted request must close a span"
        );
        for sp in &audit.spans {
            assert_eq!(
                sp.components_sum(),
                sp.end_to_end(),
                "attribution leak on request {}",
                sp.id
            );
            assert!(sp.enqueue_cycle <= sp.dispatch_cycle);
            assert!(sp.dispatch_cycle <= sp.complete_cycle);
        }
        // the totals invariant lifts from the spans
        let (e2e, adm, batch, queue, fault, exec) = audit.totals();
        assert_eq!(e2e, adm + batch + queue + fault + exec);
        // Σ episode cycles_lost is exactly Σ span fault_stall: every
        // drain interval belongs to exactly one episode
        let span_stall: u64 = audit.spans.iter().map(|s| s.fault_stall).sum();
        let ep_lost: u64 = audit.episodes.iter().map(|e| e.cycles_lost).sum();
        assert_eq!(ep_lost, span_stall, "stall cycles must attribute to episodes");
        let (wide, _) = run(g.usize_in(2, 6));
        assert_eq!(
            render_ledger(&audit),
            render_ledger(&wide),
            "executor width leaked into the ledger"
        );
    });
}

/// Feed a synthetic stream into a fresh ledger over `lanes`-wide chips.
fn fold(lane_counts: &[usize], events: &[(u64, E)], horizon: u64) -> hyca::obs::attrib::AuditReport {
    let mut ledger = SpanLedger::new(lane_counts);
    for &(cycle, event) in events {
        ledger.observe(cycle, event);
    }
    ledger.finish(horizon, &[])
}

#[test]
fn queue_wait_is_the_all_lanes_busy_measure() {
    // one lane, occupied [0, 30): a request enqueued at 10 waits 20
    // cycles head-of-line + 10 cycles batch formation, then executes 10
    let report = fold(
        &[1],
        &[
            (0, E::BatchFormed { batch: 0, chip: 0, lane: 0, size: 1 }),
            (10, E::RequestEnqueue { id: 7, chip: 0 }),
            (30, E::LaneFree { chip: 0, lane: 0 }),
            (40, E::BatchFormed { batch: 1, chip: 0, lane: 0, size: 1 }),
            (40, E::RequestDispatch { id: 7, chip: 0, batch: 1 }),
            (50, E::RequestComplete { id: 7, chip: 0, batch: 1 }),
            (50, E::LaneFree { chip: 0, lane: 0 }),
        ],
        50,
    );
    assert_eq!(report.spans.len(), 1);
    let sp = &report.spans[0];
    assert_eq!((sp.queue_wait, sp.batch_wait, sp.fault_stall), (20, 10, 0));
    assert_eq!(sp.execution, 10);
    assert_eq!(sp.components_sum(), sp.end_to_end());
    // the chip summary integrates the same measures
    assert_eq!(report.chips[0].hol_cycles, 40, "[0,30) + [40,50)");
    assert_eq!(report.chips[0].busy_lane_cycles, 40);
    assert_eq!(report.chips[0].served, 1);
}

#[test]
fn drain_overlap_counts_as_fault_stall_not_queue_wait() {
    // the chip drains [20, 60) while its only lane is busy [0, 70):
    // the overlap charges fault_stall (drain takes precedence), the
    // rest of the busy window charges queue_wait
    let report = fold(
        &[1],
        &[
            (0, E::BatchFormed { batch: 0, chip: 0, lane: 0, size: 1 }),
            (10, E::RequestEnqueue { id: 0, chip: 0 }),
            (20, E::ChipDrain { chip: 0 }),
            (60, E::ChipReadmit { chip: 0 }),
            (70, E::LaneFree { chip: 0, lane: 0 }),
            (80, E::BatchFormed { batch: 1, chip: 0, lane: 0, size: 1 }),
            (80, E::RequestDispatch { id: 0, chip: 0, batch: 1 }),
            (95, E::RequestComplete { id: 0, chip: 0, batch: 1 }),
            (95, E::LaneFree { chip: 0, lane: 0 }),
        ],
        100,
    );
    let sp = &report.spans[0];
    // wait [10, 80): drained 40, all-busy-not-drained [10,20)+[60,70)=20,
    // remainder [70, 80) = 10
    assert_eq!((sp.fault_stall, sp.queue_wait, sp.batch_wait), (40, 20, 10));
    assert_eq!(sp.components_sum(), sp.end_to_end());
    assert_eq!(report.chips[0].drained_cycles, 40);
}

#[test]
fn reshard_accrues_stall_on_the_chip_actually_held() {
    // enqueued on a draining chip 0, re-sharded to healthy chip 1 at
    // 30: stall accrues only for the [10, 30) segment on chip 0
    let report = fold(
        &[1, 1],
        &[
            (5, E::ChipDrain { chip: 0 }),
            (10, E::RequestEnqueue { id: 3, chip: 0 }),
            (30, E::RequestReshard { id: 3, from: 0, to: 1 }),
            (45, E::BatchFormed { batch: 0, chip: 1, lane: 0, size: 1 }),
            (45, E::RequestDispatch { id: 3, chip: 1, batch: 0 }),
            (55, E::RequestComplete { id: 3, chip: 1, batch: 0 }),
            (55, E::LaneFree { chip: 1, lane: 0 }),
        ],
        60,
    );
    let sp = &report.spans[0];
    assert_eq!(sp.chip, 1, "the span reports the serving chip");
    assert_eq!(sp.reshards, 1);
    assert_eq!(sp.fault_stall, 20, "[10,30) on the drained chip");
    assert_eq!(sp.batch_wait, 15, "[30,45) on the healthy chip");
    assert_eq!(sp.components_sum(), sp.end_to_end());
}

// -------------------------------------------------------------- episodes

#[test]
fn episode_extends_to_readmit_when_a_drain_starts_inside() {
    // fault at 100 drains the chip at 120; the remap lands at 150 but
    // the chip only re-admits at 200 — the episode covers the drain
    let report = fold(
        &[1],
        &[
            (100, E::FaultArrival { chip: 0, row: 1, col: 2 }),
            (120, E::ChipDrain { chip: 0 }),
            (150, E::RemapApplied { chip: 0, row: 1, col: 2 }),
            (200, E::ChipReadmit { chip: 0 }),
        ],
        300,
    );
    assert_eq!(report.episodes.len(), 1);
    let ep = &report.episodes[0];
    assert_eq!(ep.start_cycle, 100);
    assert_eq!(ep.end_cycle, Some(200), "extended to the re-admit cycle");
    assert_eq!((ep.faults, ep.remaps), (1, 1));
    assert_eq!(ep.mean_remap_latency(), Some(50.0));
}

#[test]
fn unresolved_drain_and_unrepaired_fault_leave_the_episode_open() {
    // a drain that never re-admits: the episode never ends
    let report = fold(
        &[1],
        &[
            (100, E::FaultArrival { chip: 0, row: 0, col: 0 }),
            (120, E::ChipDrain { chip: 0 }),
            (150, E::RemapApplied { chip: 0, row: 0, col: 0 }),
        ],
        300,
    );
    assert_eq!(report.episodes.len(), 1);
    assert_eq!(report.episodes[0].end_cycle, None, "open drain ⇒ open episode");
    // an unrepaired fault (no remap at all) is open too
    let report = fold(&[1], &[(80, E::FaultArrival { chip: 0, row: 3, col: 3 })], 300);
    assert_eq!(report.episodes.len(), 1);
    assert_eq!(report.episodes[0].start_cycle, 80);
    assert_eq!(report.episodes[0].end_cycle, None);
    assert_eq!(report.episodes[0].remaps, 0);
    assert_eq!(report.episodes[0].mean_remap_latency(), None);
}

#[test]
fn the_closing_remap_is_priced_and_distinct_episodes_stay_separate() {
    // two well-separated fault→remap pairs on one chip = two episodes,
    // each pricing its own closing remap
    let report = fold(
        &[1],
        &[
            (100, E::FaultArrival { chip: 0, row: 1, col: 1 }),
            (150, E::RemapApplied { chip: 0, row: 1, col: 1 }),
            (5_000, E::FaultArrival { chip: 0, row: 2, col: 2 }),
            (5_080, E::RemapApplied { chip: 0, row: 2, col: 2 }),
        ],
        10_000,
    );
    assert_eq!(report.episodes.len(), 2, "separated faults are separate episodes");
    assert_eq!(report.episodes[0].end_cycle, Some(150));
    assert_eq!(report.episodes[0].remaps, 1, "the closing remap is inside the window");
    assert_eq!(report.episodes[0].mean_remap_latency(), Some(50.0));
    assert_eq!(report.episodes[1].end_cycle, Some(5_080));
    assert_eq!(report.episodes[1].mean_remap_latency(), Some(80.0));
}

#[test]
fn overlapping_faults_merge_into_one_episode() {
    // a second fault arrives while the first is live: one episode, two
    // faults, latency priced per coord-matched FIFO pair
    let report = fold(
        &[1],
        &[
            (100, E::FaultArrival { chip: 0, row: 1, col: 1 }),
            (110, E::FaultArrival { chip: 0, row: 2, col: 2 }),
            (150, E::RemapApplied { chip: 0, row: 1, col: 1 }),
            (180, E::RemapApplied { chip: 0, row: 2, col: 2 }),
        ],
        1_000,
    );
    assert_eq!(report.episodes.len(), 1);
    let ep = &report.episodes[0];
    assert_eq!((ep.start_cycle, ep.end_cycle), (100, Some(180)));
    assert_eq!((ep.faults, ep.remaps), (2, 2));
    assert_eq!(ep.remap_latency_total, 50 + 70);
    assert_eq!(ep.remap_latency_max, 70);
}

#[test]
fn episode_charges_the_requests_it_stalled() {
    // the drain [120, 200) stalls a request for its whole second half
    let report = fold(
        &[1],
        &[
            (100, E::FaultArrival { chip: 0, row: 1, col: 1 }),
            (120, E::ChipDrain { chip: 0 }),
            (130, E::RequestEnqueue { id: 0, chip: 0 }),
            (150, E::RemapApplied { chip: 0, row: 1, col: 1 }),
            (200, E::ChipReadmit { chip: 0 }),
            (210, E::BatchFormed { batch: 0, chip: 0, lane: 0, size: 1 }),
            (210, E::RequestDispatch { id: 0, chip: 0, batch: 0 }),
            (230, E::RequestComplete { id: 0, chip: 0, batch: 0 }),
            (230, E::LaneFree { chip: 0, lane: 0 }),
        ],
        300,
    );
    assert_eq!(report.spans[0].fault_stall, 70, "[130, 200) on the drained chip");
    let ep = &report.episodes[0];
    assert_eq!(ep.requests_stalled, 1);
    assert_eq!(ep.cycles_lost, 70, "episode cost == the stall it caused");
}

// ------------------------------------------------------------ the bench

#[test]
fn bench_json_is_byte_identical_at_any_worker_count() {
    let narrow = exp_audit::bench_json(&opts(SEED, 1), true).unwrap();
    let wide = exp_audit::bench_json(&opts(SEED, 8), true).unwrap();
    assert_eq!(narrow, wide, "worker count leaked into the audit bench");
    let again = exp_audit::bench_json(&opts(SEED, 1), true).unwrap();
    assert_eq!(narrow, again);
    // the seed matters
    let other = exp_audit::bench_json(&opts(0xBEEF, 1), true).unwrap();
    assert_ne!(narrow, other);
}

#[test]
fn bench_json_has_the_documented_schema_and_diffs_clean_against_itself() {
    let json = exp_audit::bench_json(&opts(SEED, 2), true).unwrap();
    for key in [
        "\"schema\": \"hyca-audit-bench-v1\"",
        "\"presets\": [",
        "\"scenario\": \"degraded_continuity\"",
        "\"scenario\": \"open_steady\"",
        "\"scenario\": \"flash_crowd\"",
        "\"scenario\": \"open_diurnal\"",
        "\"spec_hash\":",
        "\"attribution\":",
        "\"end_to_end_cycles\":",
        "\"admission_wait_cycles\":",
        "\"batch_wait_cycles\":",
        "\"queue_wait_cycles\":",
        "\"fault_stall_cycles\":",
        "\"execution_cycles\":",
        "\"episodes\":",
        "\"chips\": [",
        "\"utilization\":",
        "\"hol_cycles\":",
    ] {
        assert!(json.contains(key), "missing {key} in:\n{json}");
    }
    // everything is simulated time — wall-clock fields are forbidden
    for forbidden in ["seconds", "wall", "ns_per"] {
        assert!(!json.contains(forbidden), "wall-clock field {forbidden:?}");
    }
    // the bench parses with the in-repo reader, and the exact-sum
    // invariant is visible in the rendered numbers
    let doc = audit::parse(&json).unwrap();
    let presets = match doc.get("presets") {
        Some(audit::Json::Arr(items)) => items,
        other => panic!("presets must be an array, got {other:?}"),
    };
    assert_eq!(presets.len(), 4);
    for p in presets {
        let attr = p.get("attribution").expect("attribution object");
        let n = |key: &str| match attr.get(key) {
            Some(audit::Json::Num(v)) => *v,
            other => panic!("{key} must be a number, got {other:?}"),
        };
        assert_eq!(
            n("end_to_end_cycles"),
            n("admission_wait_cycles")
                + n("batch_wait_cycles")
                + n("queue_wait_cycles")
                + n("fault_stall_cycles")
                + n("execution_cycles"),
            "rendered components must sum exactly"
        );
    }
    // a bench diffed against itself is clean
    let report = audit::diff_text(&json, &json).unwrap();
    assert_eq!(report.regressions(), 0);
    assert_eq!(report.notices(), 0);
}

#[test]
fn degraded_continuity_audit_actually_shows_fault_forensics() {
    // the drain preset is the forensics anchor: its audit must contain
    // at least one episode with a measured remap
    let engine = Arc::new(Engine::builtin());
    let run = exp_audit::run_preset(&engine, "degraded_continuity", &opts(SEED, 1), true).unwrap();
    assert!(!run.audit.episodes.is_empty(), "the drain scenario must produce episodes");
    assert!(run.audit.episodes.iter().any(|e| e.remaps > 0), "remaps must be priced");
}

// ------------------------------------------------------------- the diff

#[test]
fn diff_passes_identical_and_reformatted_inputs() {
    let old = r#"{"schema": "hyca-audit-bench-v1", "seed": 12648430, "x": [1, 2.5, "s"]}"#;
    // jq-style reformat: different whitespace, same structure
    let new = "{\n  \"schema\":\"hyca-audit-bench-v1\",\n  \"seed\":12648430,\n  \
               \"x\":[1,2.5,\"s\"]\n}\n";
    let report = audit::diff_text(old, new).unwrap();
    assert_eq!(report.regressions(), 0, "reformatting is not a regression:\n{}", report.render());
    assert_eq!(report.notices(), 0);
}

#[test]
fn diff_flags_a_perturbed_value_as_regression() {
    let old = r#"{"schema": "hyca-audit-bench-v1", "presets": [{"requests": 100}]}"#;
    let new = r#"{"schema": "hyca-audit-bench-v1", "presets": [{"requests": 101}]}"#;
    let report = audit::diff_text(old, new).unwrap();
    assert_eq!(report.regressions(), 1);
    assert!(report.render().contains("REGRESSION"));
    assert!(report.render().contains("presets.0.requests"));
}

#[test]
fn diff_severity_classes_match_the_documentation() {
    // missing key = regression; added key = notice
    let old = r#"{"schema": "hyca-audit-bench-v1", "a": 1, "b": 2}"#;
    let new = r#"{"schema": "hyca-audit-bench-v1", "a": 1, "c": 3}"#;
    let report = audit::diff_text(old, new).unwrap();
    assert_eq!(report.regressions(), 1, "dropping a key fails the gate");
    assert_eq!(report.notices(), 1, "adding a key is additive evolution");
    // array length change = regression
    let old = r#"{"schema": "hyca-audit-bench-v1", "xs": [1, 2]}"#;
    let new = r#"{"schema": "hyca-audit-bench-v1", "xs": [1]}"#;
    assert_eq!(audit::diff_text(old, new).unwrap().regressions(), 1);
    // type change = regression
    let old = r#"{"schema": "hyca-audit-bench-v1", "v": 1}"#;
    let new = r#"{"schema": "hyca-audit-bench-v1", "v": "1"}"#;
    assert_eq!(audit::diff_text(old, new).unwrap().regressions(), 1);
}

#[test]
fn diff_applies_the_typed_tolerance_rules() {
    // utilization carries a 1e-9 relative tolerance: formatting jitter
    // passes, real drift fails
    let old = r#"{"schema": "hyca-audit-bench-v1",
                  "presets": [{"chips": [{"utilization": 0.5}]}]}"#;
    let close = r#"{"schema": "hyca-audit-bench-v1",
                  "presets": [{"chips": [{"utilization": 0.5000000000001}]}]}"#;
    let report = audit::diff_text(old, close).unwrap();
    assert_eq!(report.regressions(), 0, "inside rel tol:\n{}", report.render());
    assert_eq!(report.notices(), 1, "within-tolerance drift is still reported");
    let far = r#"{"schema": "hyca-audit-bench-v1",
                  "presets": [{"chips": [{"utilization": 0.51}]}]}"#;
    assert_eq!(audit::diff_text(old, far).unwrap().regressions(), 1);
    // the perf schema ignores its wall-clock section wholesale
    let old = r#"{"schema": "hyca-perf-bench-v1", "timing": {"wall_ms": 10}, "d": 1}"#;
    let new = r#"{"schema": "hyca-perf-bench-v1", "timing": {"wall_ms": 99}, "d": 1}"#;
    let report = audit::diff_text(old, new).unwrap();
    assert_eq!(report.regressions(), 0, "timing is nondeterministic by design");
    assert_eq!(report.notices(), 1, "the ignored subtree is disclosed");
}

#[test]
fn diff_refuses_incomparable_inputs() {
    // different schemas are an error, not a regression count
    let a = r#"{"schema": "hyca-audit-bench-v1"}"#;
    let b = r#"{"schema": "hyca-traffic-bench-v3"}"#;
    assert!(audit::diff_text(a, b).is_err());
    // a schema-less file is not a bench baseline
    assert!(audit::diff_text(r#"{"x": 1}"#, a).is_err());
    // parse errors propagate
    assert!(audit::diff_text("{", a).is_err());
    assert!(audit::diff_text(a, r#"{"schema": "hyca-audit-bench-v1"} trailing"#).is_err());
}

#[test]
fn json_parser_handles_the_bench_grammar() {
    let doc = audit::parse(
        r#"{"s": "a\"b\\cA", "n": -1.5e3, "t": true, "f": false, "z": null,
            "arr": [[]], "obj": {"k": 0}}"#,
    )
    .unwrap();
    assert_eq!(doc.get("s").and_then(audit::Json::as_str), Some("a\"b\\cA"));
    assert_eq!(doc.get("n"), Some(&audit::Json::Num(-1500.0)));
    assert_eq!(doc.get("t"), Some(&audit::Json::Bool(true)));
    assert_eq!(doc.get("z"), Some(&audit::Json::Null));
    assert!(matches!(doc.get("arr"), Some(audit::Json::Arr(a)) if a.len() == 1));
    assert!(audit::parse("[1, 2, ]").is_err(), "trailing commas are not JSON");
    assert!(audit::parse("").is_err());
}
