//! The dedicated concurrency-proof job for the lock-free executor
//! (DESIGN.md §8, ROADMAP item 3).
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` — the cfg that also
//! switches [`hyca::loomsim`]'s facade into its instrumented build for
//! the whole library, so the deque and result slot under test here are
//! the exact sources shipping in the executor, not copies. Run it the
//! way CI does:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --manifest-path rust/Cargo.toml \
//!     --test loom_executor --release
//! ```
//!
//! Tier-1 `cargo test` already runs five of these six proofs as unit
//! tests (cheaply, via `cfg(test)`); this job exists to (a) run them in
//! release mode where exploration is fast enough to go deep, and
//! (b) add the expensive stale-read/wrap-around scenario that is too
//! slow for the tier-1 wall-clock budget. Every proof must report a
//! *complete* exploration — hitting a run budget would mean the proof
//! proved nothing.

#![cfg(loom)]

use hyca::serve::proofs;

/// Assert the exploration exhausted its schedule space and actually
/// exercised more than one interleaving (a 1-schedule "proof" would
/// mean the scenario lost its concurrency).
fn proved(name: &str, e: hyca::loomsim::Explored) {
    assert!(e.complete, "{name}: exploration hit the run budget — not a proof");
    assert!(e.schedules > 1, "{name}: only {} schedule(s) explored", e.schedules);
    eprintln!("[loom] {name}: {} schedules, complete", e.schedules);
}

#[test]
fn steal_vs_pop_boundary() {
    proved("steal_vs_pop_boundary", proofs::steal_vs_pop_boundary());
}

#[test]
fn two_thieves_one_item() {
    proved("two_thieves_one_item", proofs::two_thieves_one_item());
}

#[test]
fn wrap_around_slot_reuse() {
    proved("wrap_around_slot_reuse", proofs::wrap_around_slot_reuse());
}

#[test]
fn grow_during_inflight_steal() {
    proved("grow_during_inflight_steal", proofs::grow_during_inflight_steal());
}

#[test]
fn stale_read_discarded_by_top_cas() {
    proved("stale_read_discarded_by_top_cas", proofs::stale_read_discarded_by_top_cas());
}

#[test]
fn slot_publish_race() {
    proved("slot_publish_race", proofs::slot_publish_race());
}
