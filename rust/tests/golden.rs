//! Golden-file regression tests for the experiment coordinator.
//!
//! `fig2`, `fig10` and `table1` run with a pinned fast configuration
//! (`configs: 50, seed: 0xC0FFEE, threads: 2`) and their rendered
//! tables must match the snapshots under `rust/tests/golden/`
//! byte-for-byte. Regenerate intentionally with:
//!
//! ```sh
//! HYCA_BLESS=1 cargo test -q --test golden
//! ```
//!
//! A missing snapshot is written on first run (and the run passes) so a
//! fresh clone bootstraps itself; commit the generated files to arm the
//! regression check. Independent of the snapshots, the thread-invariance
//! test asserts the reproducibility contract directly: the same seed
//! must produce byte-identical tables at any `--threads` value
//! (`faults::montecarlo`'s per-index PRNG splitting).

use std::path::PathBuf;

use hyca::coordinator::{find, RunOpts};
use hyca::util::table::Table;

const GOLDEN_IDS: [&str; 3] = ["fig2", "fig10", "table1"];

fn golden_opts(threads: usize) -> RunOpts {
    RunOpts {
        fast: true,
        configs: 50,
        seed: 0xC0FFEE,
        threads,
        out_dir: std::env::temp_dir().join("hyca_golden_results"),
        // pin fig2 to the builtin model: snapshots must not depend on
        // whatever artifact state this machine happens to have
        builtin_model: true,
        ..RunOpts::default()
    }
}

fn render(tables: &[Table]) -> String {
    let mut s = String::new();
    for t in tables {
        s.push_str(&t.to_markdown());
        s.push('\n');
    }
    s
}

fn run_rendered(id: &str, threads: usize) -> String {
    let exp = find(id).unwrap_or_else(|| panic!("unknown experiment {id}"));
    let tables = exp
        .run(&golden_opts(threads))
        .unwrap_or_else(|e| panic!("{id} failed: {e}"));
    assert!(!tables.is_empty(), "{id}: no tables");
    for t in &tables {
        assert!(!t.rows.is_empty(), "{id}: empty table {:?}", t.title);
    }
    render(&tables)
}

fn golden_path(id: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("{id}.md"))
}

fn check_golden(id: &str) {
    let got = run_rendered(id, 2);
    let path = golden_path(id);
    let bless = std::env::var("HYCA_BLESS").is_ok();
    if bless || !path.exists() {
        // Under HYCA_GOLDEN_STRICT (set by CI's replay step) a missing
        // snapshot is an error, not a bless — otherwise a fresh checkout
        // would auto-bless forever and the regression check would pass
        // vacuously. Plain `cargo test` on a fresh clone stays green.
        if !bless && std::env::var("HYCA_GOLDEN_STRICT").is_ok() {
            panic!(
                "{id}: golden snapshot {} is missing under HYCA_GOLDEN_STRICT — \
                 generate with `HYCA_BLESS=1 cargo test -q --test golden` and commit it",
                path.display()
            );
        }
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &got).unwrap();
        eprintln!(
            "blessed golden snapshot {} ({}); commit it to arm the check",
            path.display(),
            if bless { "HYCA_BLESS=1" } else { "was missing" }
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got, want,
        "{id}: rendered tables diverged from {} — if the change is \
         intentional, regenerate with HYCA_BLESS=1",
        path.display()
    );
}

#[test]
fn golden_fig2() {
    check_golden("fig2");
}

#[test]
fn golden_fig10() {
    check_golden("fig10");
}

#[test]
fn golden_table1() {
    check_golden("table1");
}

/// The reproducibility contract behind the snapshots: same seed, any
/// thread count → byte-identical tables.
#[test]
fn golden_experiments_are_thread_invariant() {
    for id in GOLDEN_IDS {
        let one = run_rendered(id, 1);
        let two = run_rendered(id, 2);
        let many = run_rendered(id, 7);
        assert_eq!(one, two, "{id}: threads=1 vs threads=2 diverged");
        assert_eq!(two, many, "{id}: threads=2 vs threads=7 diverged");
    }
}

/// Structural sanity independent of snapshot contents, so the suite
/// still asserts something meaningful on a fresh (unblessed) clone.
#[test]
fn golden_experiments_have_expected_shape() {
    let fig2 = run_rendered("fig2", 2);
    assert!(fig2.contains("PER(%)") && fig2.contains("clean"));
    let fig10 = run_rendered("fig10", 2);
    assert!(fig10.contains("random") && fig10.contains("clustered"));
    assert!(fig10.contains("HyCA32"));
    let table1 = run_rendered("table1", 2);
    assert!(table1.contains("scan_cycles") && table1.contains("VGG"));
}
