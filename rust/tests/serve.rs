//! Serving-subsystem acceptance tests.
//!
//! 1. **Golden determinism**: the `BENCH_serve.json` metrics are a pure
//!    function of the master seed — byte-identical at any `--workers`
//!    (executor thread) value and across repeated runs. Wall-clock
//!    fields do not exist in the JSON by construction.
//! 2. **Scan-and-repair scenario**: with mid-run fault arrivals, the
//!    accuracy timeline shows a dip, a scan detection, a live remap,
//!    and recovery to *exactly* 1.0 — the bit-exactness contract of the
//!    builtin model extended to serving. Whether a given seed's
//!    arrivals actually flip a prediction depends on which PE fails, so
//!    the test scans a handful of seeds for a visible dip (the scan is
//!    itself deterministic) and then asserts the full story on it.

use hyca::coordinator::{exp_serve, RunOpts};
use hyca::serve::scan_agent::EventKind;

fn opts(seed: u64, threads: usize) -> RunOpts {
    RunOpts {
        seed,
        threads,
        out_dir: std::env::temp_dir().join("hyca_serve_results"),
        builtin_model: true,
        ..RunOpts::default()
    }
}

#[test]
fn bench_json_is_byte_identical_at_any_executor_width() {
    let narrow = exp_serve::bench_json(&opts(0xC0FFEE, 1), true).unwrap();
    let wide = exp_serve::bench_json(&opts(0xC0FFEE, 4), true).unwrap();
    assert_eq!(
        narrow, wide,
        "executor width leaked into the serving metrics"
    );
    // repeat run: byte-identical again
    let again = exp_serve::bench_json(&opts(0xC0FFEE, 1), true).unwrap();
    assert_eq!(narrow, again);
    // and the seed actually matters
    let other = exp_serve::bench_json(&opts(0xBEEF, 1), true).unwrap();
    assert_ne!(narrow, other);
}

#[test]
fn bench_json_has_the_documented_schema() {
    let json = exp_serve::bench_json(&opts(0xC0FFEE, 2), true).unwrap();
    for key in [
        "\"schema\": \"hyca-serve-bench-v1\"",
        "\"grid\": [",
        "\"workers\": 1",
        "\"max_batch\": 8",
        "\"throughput_imgs_per_mcycle\":",
        "\"p50_cycles\":",
        "\"p99_cycles\":",
    ] {
        assert!(json.contains(key), "missing {key} in:\n{json}");
    }
    // no wall-clock fields, ever
    for forbidden in ["seconds", "wall", "ns_per"] {
        assert!(!json.contains(forbidden), "wall-clock field {forbidden:?}");
    }
}

#[test]
fn scenario_report_is_invariant_to_executor_width() {
    let a = exp_serve::scenario_report(&opts(0xC0FFEE, 1), true).unwrap();
    let b = exp_serve::scenario_report(&opts(0xC0FFEE, 5), true).unwrap();
    assert_eq!(a.digest(), b.digest());
}

#[test]
fn fault_scenario_dips_detects_remaps_and_recovers_exactly() {
    // Find a seed whose arrivals visibly flip at least one prediction
    // AND whose last detection lands early enough that recovery is
    // temporally possible within the run (a fault can in principle keep
    // escaping scan windows past the end of traffic — §IV-D). Given
    // such a seed, exact recovery is a *structural* property the
    // assertions below verify — the search only selects observability,
    // never the outcome.
    let mut hit = None;
    for seed in 0..24u64 {
        let report = exp_serve::scenario_report(&opts(seed, 2), true).unwrap();
        let arrivals = report
            .events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::FaultArrival(_)))
            .count();
        let dipped = report
            .windows
            .iter()
            .any(|w| w.accuracy().map(|a| a < 1.0).unwrap_or(false));
        let window_len = report.windows[0].end_cycle - report.windows[0].start_cycle;
        let timely = report
            .events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::ScanDetection(_)))
            .map(|e| e.cycle)
            .max()
            .map(|last| last + 3 * window_len <= report.total_cycles)
            .unwrap_or(false);
        if arrivals > 0 && dipped && report.unrepaired == 0 && timely {
            hit = Some((seed, report));
            break;
        }
    }
    let (seed, report) =
        hit.expect("no seed in 0..24 produced a visible, timely-detected dip — scenario broken");

    // the timeline tells the full story, in order:
    // fault arrival → accuracy dip → scan detection (= live remap)
    let first_arrival = report
        .events
        .iter()
        .find(|e| matches!(e.kind, EventKind::FaultArrival(_)))
        .unwrap()
        .cycle;
    let detections: Vec<u64> = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::ScanDetection(_)))
        .map(|e| e.cycle)
        .collect();
    assert!(
        !detections.is_empty(),
        "seed {seed}: arrivals were never detected"
    );
    assert!(
        detections.iter().all(|&d| d > first_arrival),
        "detection cannot precede the first arrival"
    );
    assert_eq!(
        report.unrepaired, 0,
        "seed {seed}: every arrived fault must be remapped by the end"
    );

    // recovery is EXACT: once the last remap lands and in-flight faulty
    // batches drain, accuracy returns to 1.0 — the final populated
    // window must be perfect, and every misprediction must complete
    // before the last detection + one batch drain.
    assert_eq!(
        report.final_window_accuracy(),
        Some(1.0),
        "seed {seed}: accuracy did not recover to exactly 1.0"
    );
    let dip_windows: Vec<usize> = report
        .windows
        .iter()
        .filter(|w| w.accuracy().map(|a| a < 1.0).unwrap_or(false))
        .map(|w| w.index)
        .collect();
    assert!(!dip_windows.is_empty());
    let last_detection = *detections.iter().max().unwrap();
    let last_dip_end = report
        .windows
        .iter()
        .filter(|w| dip_windows.contains(&w.index))
        .map(|w| w.end_cycle)
        .max()
        .unwrap();
    // drain allowance: a faulty batch dispatched just before the last
    // remap may run for up to one full batch (~1.7 windows here), and
    // the dip window containing its completion rounds up by one more
    let window_len = report.windows[0].end_cycle - report.windows[0].start_cycle;
    assert!(
        last_dip_end <= last_detection + 3 * window_len,
        "seed {seed}: mispredictions persist long after the last remap \
         (dip until {last_dip_end}, last remap {last_detection})"
    );
    // overall accuracy reflects a real but bounded disturbance
    assert!(report.accuracy < 1.0);
    assert!(report.accuracy > 0.25, "the dip should be a dip, not an outage");
}

#[test]
fn serve_experiment_tables_render() {
    let (tables, json) = exp_serve::run_full(&opts(0xC0FFEE, 2), true).unwrap();
    assert_eq!(tables.len(), 3);
    let grid = tables[0].to_markdown();
    assert!(grid.contains("imgs_per_Mcycle") && grid.contains("p99_cycles"));
    let timeline = tables[1].to_markdown();
    assert!(timeline.contains("accuracy") && timeline.contains("events"));
    let summary = tables[2].to_markdown();
    assert!(summary.contains("recovered_exactly"));
    assert!(json.starts_with("{\n"));
}
