//! Executor bench: shared-queue vs static partition vs mutex vs
//! lock-free work stealing at 1/2/4/8 threads on a fleet_default-shaped
//! job mix — the micro-level companion of `repro perf` (which sweeps
//! chip counts and persists BENCH_perf.json; this harness gives
//! benchkit-quality per-plan deltas against the previous run's
//! baseline). The `mutex/*` vs `lockfree/*` pairs are the headline:
//! same jobs, same homes, only the deque differs.
use std::sync::Arc;

use hyca::benchkit::Bench;
use hyca::coordinator::exp_fleet::fleet_cell;
use hyca::fleet::{simulate_fleet, RoutingPolicy};
use hyca::inference::Engine;
use hyca::serve::executor::{self, DequeImpl, ExecMode, ExecPlan};
use hyca::serve::BatchJob;

fn main() {
    let engine = Arc::new(Engine::builtin());
    let mut b = Bench::new("executor");

    // the fleet_default-shaped mix: 8 chips, round-robin, smoke sizing
    // (exactly what BENCH_fleet.json's biggest grid row replays)
    let cfg = fleet_cell(0xC0FFEE, 8, RoutingPolicy::RoundRobin, true, 1);
    let timeline = simulate_fleet(&engine, &cfg);
    let jobs: Vec<&BatchJob> = timeline.jobs.iter().map(|j| &j.job).collect();
    let affinity: Vec<usize> = timeline.jobs.iter().map(|j| j.chip).collect();
    let served: usize = jobs.iter().map(|j| j.image_idxs.len()).sum();

    // (mode, deque, home_set) plans, baseline first — labels match the
    // BENCH_perf.json executor column, with the home-set row suffixed
    let plans: [(ExecMode, DequeImpl, usize, &str); 5] = [
        (ExecMode::SharedQueue, DequeImpl::LockFree, 1, "shared"),
        (ExecMode::WorkSteal { steal: false }, DequeImpl::LockFree, 1, "steal_off"),
        (ExecMode::WorkSteal { steal: true }, DequeImpl::Mutex, 1, "mutex"),
        (ExecMode::WorkSteal { steal: true }, DequeImpl::LockFree, 1, "lockfree"),
        (ExecMode::WorkSteal { steal: true }, DequeImpl::LockFree, 2, "lockfree_hs2"),
    ];

    for threads in [1usize, 2, 4, 8] {
        for (mode, deque, home_set, name) in plans {
            let aff = match mode {
                ExecMode::SharedQueue => None,
                ExecMode::WorkSteal { .. } => Some(affinity.as_slice()),
            };
            let plan = ExecPlan {
                threads,
                mode,
                deque,
                affinity: aff,
                home_set,
                queue_cap: cfg.queue_cap,
            };
            b.bench_units(format!("{name}/t{threads}"), Some(served as f64), || {
                std::hint::black_box(executor::execute_plan(&engine, &jobs, &plan).unwrap());
            });
        }
    }

    b.report();
}
