//! Executor bench: shared-queue vs work-stealing (steal on/off) at
//! 1/2/4/8 threads on a fleet_default-shaped job mix — the micro-level
//! companion of `repro perf` (which sweeps chip counts and persists
//! BENCH_perf.json; this harness gives benchkit-quality per-topology
//! deltas against the previous run's baseline).
use std::sync::Arc;

use hyca::benchkit::Bench;
use hyca::coordinator::exp_fleet::fleet_cell;
use hyca::fleet::{simulate_fleet, RoutingPolicy};
use hyca::inference::Engine;
use hyca::serve::executor::{self, ExecMode};
use hyca::serve::BatchJob;

fn main() {
    let engine = Arc::new(Engine::builtin());
    let mut b = Bench::new("executor");

    // the fleet_default-shaped mix: 8 chips, round-robin, smoke sizing
    // (exactly what BENCH_fleet.json's biggest grid row replays)
    let cfg = fleet_cell(0xC0FFEE, 8, RoutingPolicy::RoundRobin, true, 1);
    let timeline = simulate_fleet(&engine, &cfg);
    let jobs: Vec<&BatchJob> = timeline.jobs.iter().map(|j| &j.job).collect();
    let affinity: Vec<usize> = timeline.jobs.iter().map(|j| j.chip).collect();
    let served: usize = jobs.iter().map(|j| j.image_idxs.len()).sum();

    for threads in [1usize, 2, 4, 8] {
        b.bench_units(
            format!("shared/t{threads}"),
            Some(served as f64),
            || {
                std::hint::black_box(
                    executor::execute(
                        &engine,
                        &jobs,
                        None,
                        threads,
                        ExecMode::SharedQueue,
                        cfg.queue_cap,
                    )
                    .unwrap(),
                );
            },
        );
        for steal in [false, true] {
            let mode = ExecMode::WorkSteal { steal };
            b.bench_units(
                format!("{}/t{threads}", mode.label()),
                Some(served as f64),
                || {
                    std::hint::black_box(
                        executor::execute(
                            &engine,
                            &jobs,
                            Some(&affinity),
                            threads,
                            mode,
                            cfg.queue_cap,
                        )
                        .unwrap(),
                    );
                },
            );
        }
    }

    b.report();
}
