//! Bench + regeneration for Fig. 10: FFP of all four schemes under
//! both fault models — the paper's headline reliability figure.
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::faults::montecarlo::FaultModel;
use hyca::redundancy::{evaluate_scheme, hyca::HycaScheme};

fn main() {
    let opts = RunOpts { configs: 3000, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig10").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig10", &tables).unwrap();

    let mut b = Bench::new("fig10");
    let dims = Dims::PAPER;
    let hyca = HycaScheme::paper(32);
    for m in FaultModel::both() {
        b.bench_units(format!("hyca_ffp_1000cfg/{}", m.label()), Some(1000.0), || {
            std::hint::black_box(evaluate_scheme(&hyca, dims, 0.03, m, 1, 1000, 1));
        });
    }
    b.report();
}
