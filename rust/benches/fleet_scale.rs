//! Bench for the fleet subsystem: wall-clock cost of the cluster
//! discrete-event simulation and of replaying a multi-chip timeline on
//! the real worker pool, across cluster sizes and routing policies.
//! (The *simulated* metrics are deterministic and live in
//! BENCH_fleet.json via `repro fleet`; this harness measures what the
//! host machine actually sustains.)
use std::sync::Arc;

use hyca::benchkit::Bench;
use hyca::coordinator::exp_fleet::fleet_cell;
use hyca::fleet::{simulate_fleet, RoutingPolicy};
use hyca::inference::Engine;
use hyca::serve::executor::{self, ExecMode};
use hyca::serve::BatchJob;

fn main() {
    let engine = Arc::new(Engine::builtin());
    let mut b = Bench::new("fleet");

    // cluster timeline simulation alone (pure, no inference) at
    // increasing cluster sizes
    for chips in [1usize, 4, 8] {
        let cfg = fleet_cell(0xC0FFEE, chips, RoutingPolicy::HealthWeighted, true, 1);
        let req = cfg.total_requests as f64;
        b.bench_units(format!("simulate_fleet/chips{chips}"), Some(req), || {
            std::hint::black_box(simulate_fleet(&engine, &cfg));
        });
    }

    // routing policy overhead at a fixed cluster size
    for policy in RoutingPolicy::all() {
        let cfg = fleet_cell(0xC0FFEE, 4, policy, true, 1);
        let req = cfg.total_requests as f64;
        b.bench_units(format!("simulate_fleet/{policy}"), Some(req), || {
            std::hint::black_box(simulate_fleet(&engine, &cfg));
        });
    }

    // executing a multi-chip timeline on the work-stealing executor
    // with per-chip affinity (what fleet::run does): images/second at
    // various widths, with the legacy shared queue as the reference
    let cfg = fleet_cell(0xC0FFEE, 4, RoutingPolicy::RoundRobin, true, 1);
    let timeline = simulate_fleet(&engine, &cfg);
    let jobs: Vec<&BatchJob> = timeline.jobs.iter().map(|j| &j.job).collect();
    let affinity: Vec<usize> = timeline.jobs.iter().map(|j| j.chip).collect();
    let served: usize = jobs.iter().map(|j| j.image_idxs.len()).sum();
    for threads in [1usize, 2, 4] {
        b.bench_units(
            format!("executor_steal/chips4_t{threads}"),
            Some(served as f64),
            || {
                std::hint::black_box(
                    executor::execute(
                        &engine,
                        &jobs,
                        Some(&affinity),
                        threads,
                        ExecMode::WorkSteal { steal: true },
                        8,
                    )
                    .unwrap(),
                );
            },
        );
        b.bench_units(
            format!("executor_shared/chips4_t{threads}"),
            Some(served as f64),
            || {
                std::hint::black_box(
                    executor::execute(&engine, &jobs, None, threads, ExecMode::SharedQueue, 8)
                        .unwrap(),
                );
            },
        );
    }

    b.report();
}
