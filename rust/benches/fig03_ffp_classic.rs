//! Bench + regeneration for Fig. 3: classical-scheme FFP on the 32×32
//! array (random faults). Times the Monte-Carlo hot path per scheme.
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::faults::montecarlo::FaultModel;
use hyca::redundancy::{cr::ColumnRedundancy, dr::DiagonalRedundancy, evaluate_scheme, rr::RowRedundancy, Scheme};

fn main() {
    let opts = RunOpts { configs: 3000, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig3").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig3", &tables).unwrap();

    let mut b = Bench::new("fig03");
    let dims = Dims::PAPER;
    for (name, s) in [
        ("rr", &RowRedundancy::default() as &dyn Scheme),
        ("cr", &ColumnRedundancy::default()),
        ("dr", &DiagonalRedundancy),
    ] {
        b.bench_units(format!("ffp_1000cfg/{name}"), Some(1000.0), || {
            std::hint::black_box(evaluate_scheme(s, dims, 0.02, FaultModel::Random, 1, 1000, 1));
        });
    }
    b.report();
}
