//! Bench + regeneration for Fig. 13: NN runtime vs array width.
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::perfmodel::networks;

fn main() {
    let opts = RunOpts { out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig13").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig13", &tables).unwrap();

    let mut b = Bench::new("fig13");
    let nets = networks::benchmark();
    b.bench_units("runtime_model_4nets_x_61widths", Some(4.0 * 61.0), || {
        for net in &nets {
            for w in 4..=64usize {
                std::hint::black_box(net.cycles(Dims::new(32, w)));
            }
        }
    });
    b.report();
}
