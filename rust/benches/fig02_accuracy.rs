//! Bench + regeneration for Fig. 2: end-to-end accuracy vs PER through
//! the active inference backend (compiled artifacts when present, the
//! builtin model on the native backend otherwise). Also times the
//! serving hot path (one batch through the backend).
use hyca::benchkit::{Bench, BenchConfig};
use hyca::coordinator::{find, report, RunOpts};
use hyca::inference::{Engine, LayerMasks};
use std::time::Duration;

fn main() {
    let engine = Engine::auto();
    let opts = RunOpts { fast: true, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig2").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig2", &tables).unwrap();

    let mut b = Bench::with_config(
        "fig02",
        BenchConfig { warmup: Duration::from_millis(500), samples: 10, min_sample: Duration::from_millis(100) },
    );
    let geometry = engine.geometry();
    let masks = LayerMasks::identity(&geometry);
    let images = engine.eval.images[..engine.batch].to_vec();
    b.bench_units(
        format!(
            "{}_infer_batch{}",
            engine.backend.name().replace(':', "_"),
            engine.batch
        ),
        Some(engine.batch as f64),
        || {
            std::hint::black_box(engine.predict_batch(&images, &masks).unwrap());
        },
    );
    b.bench_units("mask_build_30faults", Some(1.0), || {
        let cfg = hyca::faults::montecarlo::FaultModel::Random.sample_indexed(
            1, 1, hyca::array::Dims::PAPER, 0.03,
        );
        std::hint::black_box(LayerMasks::from_faults(&geometry, &cfg, &|_, _| false, 1e-4, 1));
    });
    b.report();
}
