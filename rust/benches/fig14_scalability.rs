//! Bench + regeneration for Fig. 14: FFP scalability across array sizes.
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::faults::montecarlo::FaultModel;
use hyca::redundancy::{evaluate_scheme, hyca::HycaScheme};

fn main() {
    let opts = RunOpts { configs: 1500, fast: true, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig14").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig14", &tables).unwrap();

    let mut b = Bench::new("fig14");
    for dims in hyca::coordinator::exp_fig14::array_sizes() {
        let s = HycaScheme::paper(dims.cols);
        b.bench_units(format!("hyca_ffp_500cfg/{dims}"), Some(500.0), || {
            std::hint::black_box(evaluate_scheme(&s, dims, 0.02, FaultModel::Random, 1, 500, 1));
        });
    }
    b.report();
}
