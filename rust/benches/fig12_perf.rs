//! Bench + regeneration for Fig. 12: normalised NN performance across
//! schemes (Scale-sim-analogue model over surviving arrays).
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::faults::montecarlo::FaultModel;
use hyca::perfmodel::{mean_normalised_perf, networks, DegradedPerf};
use hyca::redundancy::hyca::HycaScheme;

fn main() {
    let opts = RunOpts { configs: 800, fast: true, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig12").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig12", &tables).unwrap();

    let mut b = Bench::new("fig12");
    let dims = Dims::PAPER;
    let net = networks::vgg16();
    let dp = DegradedPerf::new(&net, dims);
    let full = dp.cycles(dims.cols).unwrap();
    let hyca = HycaScheme::paper(32);
    b.bench_units("vgg_norm_perf_500cfg", Some(500.0), || {
        std::hint::black_box(mean_normalised_perf(
            &hyca, &dp, full, dims, 0.04, FaultModel::Random, 1, 500, 1,
        ));
    });
    b.report();
}
