//! Bench + regeneration for Fig. 11: normalised remaining computing
//! power under the column-discard degradation policy.
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::faults::montecarlo::FaultModel;
use hyca::redundancy::{evaluate_scheme, rr::RowRedundancy, hyca::HycaScheme, Scheme};

fn main() {
    let opts = RunOpts { configs: 3000, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig11").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig11", &tables).unwrap();

    let mut b = Bench::new("fig11");
    let dims = Dims::PAPER;
    for (name, s) in [
        ("rr", &RowRedundancy::default() as &dyn Scheme),
        ("hyca32", &HycaScheme::paper(32)),
    ] {
        b.bench_units(format!("power_1000cfg/{name}"), Some(1000.0), || {
            std::hint::black_box(evaluate_scheme(s, dims, 0.06, FaultModel::Random, 1, 1000, 1));
        });
    }
    b.report();
}
