//! Bench + regeneration for Fig. 15: unified vs grouped DPPU structure.
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::hyca::dppu::DppuConfig;
use hyca::hyca::schedule::simulate_window_drain;

fn main() {
    let opts = RunOpts { configs: 1500, fast: true, out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig15").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig15", &tables).unwrap();

    let mut b = Bench::new("fig15");
    let _ = Dims::PAPER;
    for size in hyca::coordinator::exp_fig15::DPPU_SIZES {
        b.bench(format!("window_drain_sim/size{size}"), move || {
            std::hint::black_box(simulate_window_drain(&DppuConfig::paper(size), 32, size + 7));
            std::hint::black_box(simulate_window_drain(&DppuConfig::unified(size), 32, size + 7));
        });
    }
    b.report();
}
