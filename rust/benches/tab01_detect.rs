//! Bench + regeneration for Table I: fault-detection scan coverage.
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};
use hyca::faults::random;
use hyca::faults::stuckat::sample_stuck_mask;
use hyca::hyca::detect::simulate_scan;
use hyca::util::rng::Pcg32;

fn main() {
    let opts = RunOpts { out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("table1").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "table1", &tables).unwrap();

    let mut b = Bench::new("tab01");
    for n in [16usize, 32, 64, 128] {
        let dims = Dims::new(n, n);
        let mut rng = Pcg32::new(1, 0);
        let cfg = random::sample_exact(&mut rng, dims, 8);
        let masks: Vec<_> = (0..8).map(|_| sample_stuck_mask(&mut rng, 1e-4, 576)).collect();
        b.bench_units(format!("scan_sim/{dims}"), Some((n * n) as f64), move || {
            let mut r = Pcg32::new(2, 0);
            std::hint::black_box(simulate_scan(&cfg, &masks, 8, &mut r));
        });
    }
    b.report();
}
