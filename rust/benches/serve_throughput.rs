//! Bench for the serving subsystem: wall-clock throughput of the real
//! worker pool replaying a simulated timeline, across executor widths
//! and batch caps. (The *simulated* metrics are deterministic and live
//! in BENCH_serve.json via `repro serve`; this harness measures what
//! the host machine actually sustains.)
use std::sync::Arc;

use hyca::benchkit::Bench;
use hyca::coordinator::exp_serve::grid_cell;
use hyca::inference::Engine;
use hyca::serve::{pool, simulate_timeline, ServeConfig};

/// Exactly the grid-cell workload BENCH_serve.json reports (smoke
/// sizing), with the requested executor width.
fn cfg(lanes: usize, max_batch: usize) -> ServeConfig {
    grid_cell(0xC0FFEE, lanes, max_batch, true, 1)
}

fn main() {
    let engine = Arc::new(Engine::builtin());
    let mut b = Bench::new("serve");

    // timeline simulation alone (pure, no inference)
    let sim_cfg = cfg(4, 8);
    let sim_req = sim_cfg.total_requests as f64;
    b.bench_units("simulate_timeline/grid_cell", Some(sim_req), || {
        std::hint::black_box(simulate_timeline(&engine, &sim_cfg));
    });

    // pool execution: images/second at various executor widths
    for (threads, max_batch) in [(1usize, 1usize), (1, 8), (2, 8), (4, 8), (4, 32)] {
        let c = cfg(4, max_batch);
        let timeline = simulate_timeline(&engine, &c);
        let jobs = timeline.jobs;
        let served: usize = jobs.iter().map(|j| j.image_idxs.len()).sum();
        b.bench_units(
            format!("pool_execute/t{threads}_b{max_batch}"),
            Some(served as f64),
            || {
                std::hint::black_box(
                    pool::execute(&engine, &jobs, threads, 8).unwrap(),
                );
            },
        );
    }

    b.report();
}
