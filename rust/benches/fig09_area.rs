//! Bench + regeneration for Fig. 9: chip-area model.
use hyca::area::{dla_area, fig9_lineup, AreaConstants};
use hyca::array::Dims;
use hyca::benchkit::Bench;
use hyca::coordinator::{find, report, RunOpts};

fn main() {
    let opts = RunOpts { out_dir: "results/bench".into(), ..RunOpts::default() };
    let tables = find("fig9").unwrap().run(&opts).unwrap();
    report::emit(&opts.out_dir, "fig9", &tables).unwrap();

    let mut b = Bench::new("fig09");
    let c = AreaConstants::default();
    b.bench_units("area_all_schemes", Some(fig9_lineup().len() as f64), || {
        for s in fig9_lineup() {
            std::hint::black_box(dla_area(&c, Dims::PAPER, s));
        }
    });
    b.report();
}
