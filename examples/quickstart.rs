//! Quickstart: sample a faulty 32×32 computing array, try to repair it
//! with the four redundancy schemes, and print what survives.
//!
//! ```sh
//! cargo run --release --example quickstart [PER%] [seed]
//! ```

use hyca::array::Dims;
use hyca::faults::montecarlo::FaultModel;
use hyca::redundancy::{
    cr::ColumnRedundancy, dr::DiagonalRedundancy, evaluate_scheme, hyca::HycaScheme,
    rr::RowRedundancy, RepairCtx, Scheme,
};
use hyca::util::rng::Pcg32;
use hyca::util::table::{f, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let per: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2.0) / 100.0;
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(42);
    let dims = Dims::PAPER;

    // 1. sample one fault configuration
    let cfg = FaultModel::Random.sample_indexed(seed, 0, dims, per);
    println!(
        "sampled {} faulty PEs on a {dims} array at PER {:.2}% (seed {seed}):",
        cfg.count(),
        per * 100.0
    );
    for c in cfg.faulty().iter().take(12) {
        print!(" ({},{})", c.row, c.col);
    }
    if cfg.count() > 12 {
        print!(" …");
    }
    println!("\n");

    // 2. repair with each scheme
    let schemes: Vec<Box<dyn Scheme>> = vec![
        Box::new(RowRedundancy::default()),
        Box::new(ColumnRedundancy::default()),
        Box::new(DiagonalRedundancy),
        Box::new(HycaScheme::paper(32)),
    ];
    let mut t = Table::new(
        "repair outcome for this configuration",
        &["scheme", "spares", "fully functional", "surviving cols", "remaining power"],
    );
    for s in &schemes {
        let mut rng = Pcg32::split(seed, 1);
        let mut ctx = RepairCtx { per, rng: &mut rng };
        let o = s.repair(&cfg, &mut ctx);
        t.push_row(vec![
            s.name(),
            s.spare_count(dims).to_string(),
            o.fully_functional.to_string(),
            format!("{}/{}", o.surviving_cols, o.total_cols),
            f(o.remaining_power(), 3),
        ]);
    }
    println!("{}", t.to_markdown());

    // 3. Monte-Carlo: fully-functional probability at this PER
    let mut t = Table::new(
        format!("fully-functional probability at PER {:.2}% (2000 configs)", per * 100.0),
        &["scheme", "FFP", "mean remaining power"],
    );
    for s in &schemes {
        let (ffp, power) =
            evaluate_scheme(s.as_ref(), dims, per, FaultModel::Random, seed, 2000, 4);
        t.push_row(vec![s.name(), f(ffp, 4), f(power, 4)]);
    }
    println!("{}", t.to_markdown());
    println!("next: `cargo run --release -- list` for the full experiment registry");
}
