//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! 1. loads the quantized CNN into an inference backend — the
//!    AOT-compiled HLO through PJRT when built with `--features pjrt`
//!    and artifacts exist, else the hermetic native backend over the
//!    builtin model (no Python anywhere on this path either way);
//! 2. serves the held-out eval set and reports healthy accuracy;
//! 3. injects persistent faults into the simulated computing array,
//!    derives the per-layer stuck-at masks through the
//!    output-stationary mapping, and measures the degraded accuracy;
//! 4. runs the HyCA fault-detection scan, fills the FPT, repairs with
//!    the DPPU, and shows accuracy restored — plus throughput numbers
//!    for the serving loop.
//!
//! Runs out of the box; `make artifacts` + `--features pjrt` switches
//! to the compiled path. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example e2e_fault_tolerant_inference [PER%] [seed]
//! ```

use hyca::array::Dims;
use hyca::faults::ber::ber_from_per;
use hyca::faults::montecarlo::FaultModel;
use hyca::faults::stuckat::sample_stuck_mask;
use hyca::hyca::detect::simulate_scan;
use hyca::hyca::fpt::FaultPeTable;
use hyca::inference::{Engine, LayerMasks};
use hyca::redundancy::{hyca::HycaScheme, RepairCtx, Scheme};
use hyca::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let per: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(6.0) / 100.0;
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    // the functional pipeline maps the CNN onto an 8×8 array — see
    // coordinator::exp_fig02 for the model:array ratio rationale.
    let dims = Dims::new(8, 8);

    println!("== 1. load the model into an inference backend ==");
    let t0 = std::time::Instant::now();
    let engine = Engine::auto();
    println!(
        "   backend={} source={} ({} eval images, batch {}) in {:.2}s",
        engine.backend.name(),
        engine.source,
        engine.eval.images.len(),
        engine.batch,
        t0.elapsed().as_secs_f64()
    );
    let geometry = engine.geometry();

    println!("\n== 2. healthy serving ==");
    let t0 = std::time::Instant::now();
    let clean = engine.accuracy(&LayerMasks::identity(&geometry))?;
    let dt = t0.elapsed().as_secs_f64();
    let n = (engine.eval.images.len() / engine.batch) * engine.batch;
    println!(
        "   accuracy {:.4} | {} images in {:.2}s → {:.0} img/s",
        clean, n, dt, n as f64 / dt
    );

    println!("\n== 3. inject faults (PER {:.2}%) ==", per * 100.0);
    let cfg = FaultModel::Random.sample_indexed(seed, 0, dims, per);
    println!("   {} faulty PEs on the {dims} array:", cfg.count());
    for c in cfg.faulty() {
        print!(" ({},{})", c.row, c.col);
    }
    println!();
    let ber = ber_from_per(per).max(1e-6);
    let faulty_masks = LayerMasks::from_faults(&geometry, &cfg, &|_, _| false, ber, seed);
    let acc_faulty = engine.accuracy(&faulty_masks)?;
    println!("   degraded accuracy: {:.4} (clean {:.4})", acc_faulty, clean);

    println!("\n== 4. detect + repair with HyCA ==");
    let mut rng = Pcg32::new(seed, 3);
    let masks: Vec<_> = (0..cfg.count())
        .map(|_| sample_stuck_mask(&mut rng, ber, 144))
        .collect();
    let scan = simulate_scan(&cfg, &masks, 8, &mut rng);
    println!(
        "   scan ({} cycles): detected {}/{} faults{}",
        scan.total_cycles,
        scan.detected.len(),
        cfg.count(),
        if scan.escaped.is_empty() { "".to_string() } else {
            format!(" ({} escaped this window)", scan.escaped.len())
        }
    );
    let mut fpt = FaultPeTable::new(8, dims);
    for c in &scan.detected {
        fpt.insert(*c);
    }
    let scheme = HycaScheme::paper(8);
    let mut rng2 = Pcg32::new(seed, 4);
    let mut ctx = RepairCtx { per, rng: &mut rng2 };
    let outcome = scheme.repair(&cfg, &mut ctx);
    println!(
        "   DPPU(8) repair: fully_functional={} surviving {}/{} columns",
        outcome.fully_functional, outcome.surviving_cols, outcome.total_cols
    );
    let repaired_masks = LayerMasks::from_faults(
        &geometry,
        &cfg,
        &|r, c| fpt.contains(hyca::faults::Coord::new(r, c)),
        ber,
        seed,
    );
    let acc_repaired = engine.accuracy(&repaired_masks)?;
    println!("   repaired accuracy: {:.4}", acc_repaired);

    println!("\n== summary ==");
    println!(
        "   clean {:.4} → faulty {:.4} → HyCA-repaired {:.4}",
        clean, acc_faulty, acc_repaired
    );
    if scan.escaped.is_empty() && outcome.fully_functional && (acc_repaired - clean).abs() < 1e-12
    {
        println!("   full recovery: repaired accuracy identical to clean. ✔");
    }
    Ok(())
}
