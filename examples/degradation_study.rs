//! Degradation study: what happens *past* the repair capacity — the
//! paper's graceful-degradation story (Figs. 11–13) in one runnable
//! sweep, plus the unified-vs-grouped DPPU ablation (Fig. 15).
//!
//! ```sh
//! cargo run --release --example degradation_study [configs]
//! ```

use hyca::array::Dims;
use hyca::faults::montecarlo::FaultModel;
use hyca::perfmodel::{mean_normalised_perf, networks, DegradedPerf};
use hyca::redundancy::{
    cr::ColumnRedundancy, dr::DiagonalRedundancy, evaluate_scheme, hyca::HycaScheme,
    rr::RowRedundancy, Scheme,
};
use hyca::util::table::{f, Table};

fn main() {
    let configs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let dims = Dims::PAPER;
    let seed = 0xDE6;
    let threads = 4;

    // remaining computing power across the PER sweep
    let schemes: Vec<Box<dyn Scheme>> = vec![
        Box::new(RowRedundancy::default()),
        Box::new(ColumnRedundancy::default()),
        Box::new(DiagonalRedundancy),
        Box::new(HycaScheme::paper(32)),
    ];
    let mut t = Table::new(
        format!("remaining computing power ({configs} configs, random faults)"),
        &["PER(%)", "RR", "CR", "DR", "HyCA32"],
    );
    for per in [0.01, 0.02, 0.03, 0.04, 0.06] {
        let mut row = vec![f(per * 100.0, 1)];
        for s in &schemes {
            let (_, p) = evaluate_scheme(
                s.as_ref(), dims, per, FaultModel::Random, seed, configs, threads,
            );
            row.push(f(p, 3));
        }
        t.push_row(row);
    }
    println!("{}", t.to_markdown());

    // what that power means for real networks (normalised to RR)
    let mut t = Table::new(
        "normalized performance vs RR at 6% PER",
        &["network", "RR", "CR", "DR", "HyCA32"],
    );
    for net in networks::benchmark() {
        let dp = DegradedPerf::new(&net, dims);
        let full = dp.cycles(dims.cols).unwrap();
        let perfs: Vec<f64> = schemes
            .iter()
            .map(|s| {
                mean_normalised_perf(
                    s.as_ref(), &dp, full, dims, 0.06, FaultModel::Random, seed,
                    configs.min(1000), threads,
                )
            })
            .collect();
        let rr = perfs[0].max(1e-9);
        let mut row = vec![net.name.to_string()];
        for p in &perfs {
            row.push(f(p / rr, 2));
        }
        t.push_row(row);
    }
    println!("{}", t.to_markdown());

    // DPPU structure ablation: effective repair capacity (Fig. 15 root cause)
    let mut t = Table::new(
        "DPPU repair capacity per 32-cycle window (Col = 32)",
        &["size", "grouped(8)", "unified"],
    );
    for size in [16usize, 24, 32, 40, 48] {
        t.push_row(vec![
            size.to_string(),
            hyca::hyca::dppu::DppuConfig::paper(size).capacity(32).to_string(),
            hyca::hyca::dppu::DppuConfig::unified(size).capacity(32).to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("(the unified plateaus at 16/32 are Fig. 15's scalability failure)");
}
