//! SERVING WALKTHROUGH: the fault-tolerant inference service end to
//! end — dynamic batching, a real worker pool over one shared engine,
//! and online scan-and-repair while traffic keeps flowing.
//!
//! What happens:
//! 1. a closed-loop load generator drives the builtin engine through
//!    the dynamic batcher onto simulated service lanes;
//! 2. mid-run, permanent faults *arrive* on the 8×8 computing array
//!    (seeded Poisson process in cycle time) and accuracy dips;
//! 3. the background scan agent's next detection scan flags the faulty
//!    PEs; each detection inserts the PE into the FPT and the DPPU
//!    takes its outputs over — a live HyCA remap, no queue drain;
//! 4. accuracy returns to exactly 1.0 (the builtin model's labels are
//!    the clean argmax, so recovery is bit-exact, not approximate).
//!
//! ```sh
//! cargo run --release --example serving_under_faults [seed] [workers]
//! ```

use std::sync::Arc;

use hyca::coordinator::exp_serve;
use hyca::inference::Engine;
use hyca::serve::scan_agent::EventKind;
use hyca::serve::{self, CostModel};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let engine = Arc::new(Engine::builtin());
    let cfg = exp_serve::scenario_config(seed, false, workers);
    let cost = CostModel::of(&engine.params, cfg.dims);
    println!("== serving configuration ==");
    println!(
        "array {} | lanes {} | max_batch {} | clients {} | requests {}",
        cfg.dims, cfg.lanes, cfg.max_batch, cfg.clients, cfg.total_requests
    );
    println!(
        "cost model: {} cycles/image solo, {} cycles for a full batch of {} \
         ({} fill + {}/image steady)",
        cost.per_image_cycles(),
        cost.batch_cycles(cfg.max_batch),
        cfg.max_batch,
        cost.fill_per_batch,
        cost.steady_per_image
    );
    println!("executor: {workers} real worker threads over one shared Arc<Engine>");

    let report = serve::run(&engine, &cfg)?;

    println!("\n== run summary ==");
    println!(
        "served {} requests in {} batches ({} total kcycles): \
         {:.2} imgs/Mcycle, p50 {} / p99 {} cycles",
        report.total_requests,
        report.batches,
        report.total_cycles / 1000,
        report.throughput_imgs_per_mcycle,
        report.p50_cycles(),
        report.p99_cycles()
    );

    println!("\n== fault timeline ==");
    if report.events.is_empty() {
        println!("(no faults arrived this run — try another seed)");
    }
    for e in &report.events {
        match e.kind {
            EventKind::FaultArrival(c) => {
                println!("  cycle {:>8}  fault arrives at PE({},{})", e.cycle, c.row, c.col)
            }
            EventKind::ScanDetection(c) => {
                println!(
                    "  cycle {:>8}  scan detects PE({},{}) → FPT insert → DPPU remap",
                    e.cycle, c.row, c.col
                )
            }
        }
    }

    println!("\n== accuracy over time ==");
    for w in &report.windows {
        let acc = match w.accuracy() {
            Some(a) => format!("{a:.4}"),
            None => "   -  ".to_string(),
        };
        let bar = match w.accuracy() {
            Some(a) => "#".repeat((a * 40.0).round() as usize),
            None => String::new(),
        };
        println!(
            "  [{:>8}, {:>8})  n={:<3} acc={acc}  {bar}",
            w.start_cycle, w.end_cycle, w.requests
        );
    }

    println!("\n== verdict ==");
    println!(
        "overall accuracy {:.4}; unrepaired faults: {}",
        report.accuracy, report.unrepaired
    );
    if report.unrepaired == 0 && report.final_window_accuracy() == Some(1.0) {
        println!("full recovery: post-remap accuracy is exactly 1.0. ✔");
    } else {
        println!("no full recovery this run (over-capacity or undetected faults).");
    }
    println!("(benchmark grid + BENCH_serve.json: `cargo run --release -- serve`)");
    Ok(())
}
