//! Runtime fault detection demo (paper §IV-D): inject persistent
//! faults mid-operation, reserve one DPPU group as the scanner, and
//! watch the checking-list-buffer comparison find them — then push the
//! detections into the FPT and repair.
//!
//! ```sh
//! cargo run --release --example fault_detection_scan [n_faults] [seed]
//! ```

use hyca::array::Dims;
use hyca::faults::random;
use hyca::faults::stuckat::sample_stuck_mask;
use hyca::hyca::detect::{clb_bytes, scan_cycles, simulate_scan};
use hyca::hyca::fpt::FaultPeTable;
use hyca::perfmodel::networks;
use hyca::redundancy::{hyca::HycaScheme, RepairCtx, Scheme};
use hyca::util::rng::Pcg32;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_faults: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(7);
    let dims = Dims::PAPER;
    let mut rng = Pcg32::new(seed, 0);

    println!("== detection hardware ==");
    println!("scan time           : {} cycles (Row·Col + Col)", scan_cycles(dims));
    println!("checking-list buffer: {} bytes (4·W·Col, ping-pong)", clb_bytes(dims, 4));

    // wear-out faults appear at runtime
    let cfg = random::sample_exact(&mut rng, dims, n_faults);
    let masks: Vec<_> = (0..n_faults)
        .map(|_| sample_stuck_mask(&mut rng, 1e-4, 576))
        .collect();
    println!("\ninjected {} persistent faults:", cfg.count());
    for (c, m) in cfg.faulty().iter().zip(&masks) {
        println!(
            "  PE({:>2},{:>2})  and=0x{:08x} or=0x{:08x}",
            c.row, c.col, m.and_mask, m.or_mask
        );
    }

    // one full scan with the reserved DPPU group (width 8)
    let report = simulate_scan(&cfg, &masks, 8, &mut rng);
    println!("\n== scan result ==");
    for (c, cy) in report.detected.iter().zip(&report.detect_cycle) {
        println!("  detected PE({:>2},{:>2}) at cycle {}", c.row, c.col, cy);
    }
    for c in &report.escaped {
        println!(
            "  escaped  PE({:>2},{:>2}) (stuck value coincided this window — caught next scan)",
            c.row, c.col
        );
    }

    // detections feed the FPT, which drives DPPU repair
    let mut fpt = FaultPeTable::new(32, dims);
    for c in &report.detected {
        fpt.insert(*c);
    }
    println!("\nFPT now holds {}/{} entries", fpt.len(), fpt.capacity());
    let scheme = HycaScheme::paper(32);
    let mut rng2 = Pcg32::new(seed, 1);
    let mut ctx = RepairCtx { per: 0.0, rng: &mut rng2 };
    let o = scheme.repair(&cfg, &mut ctx);
    println!(
        "HyCA repair: fully functional = {}, surviving columns = {}/{}",
        o.fully_functional, o.surviving_cols, o.total_cols
    );

    // would the scan complete within each benchmark layer? (Table I)
    println!("\n== scan coverage during inference (Table I) ==");
    for net in networks::benchmark() {
        let per_layer = net.layer_cycles(dims).unwrap();
        let covered = hyca::hyca::detect::layers_covering_scan(dims, &per_layer);
        println!("  {:<8} {}/{} layers cover a full scan", net.name, covered, per_layer.len());
    }
}
