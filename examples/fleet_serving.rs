//! FLEET WALKTHROUGH: multi-chip sharded serving end to end — a
//! cluster of independently-failing chips behind a health-aware
//! router, with drain/re-admit fault-domain isolation.
//!
//! What happens:
//! 1. a closed-loop load generator drives requests through the cluster
//!    router (health-aware weighted by default) onto three chips, each
//!    a full serve-style unit with its own 8×8 array, dynamic batcher
//!    and scan agent;
//! 2. permanent faults *arrive* mid-run on each chip's array via
//!    independent seeded Poisson streams; a chip's router weight decays
//!    as its live fault count rises, shifting traffic away;
//! 3. a chip accumulating two unremapped faults crosses the drain
//!    threshold: it stops taking batches, its queue is re-sharded to
//!    healthy chips, in-flight work completes — while its scan agent
//!    keeps repairing;
//! 4. the repaired chip is re-admitted, the router restores its traffic
//!    share, and fleet accuracy returns to exactly 1.0 with zero
//!    dropped requests (the builtin model's bit-exactness contract,
//!    now cluster-wide).
//!
//! ```sh
//! cargo run --release --example fleet_serving [seed] [workers]
//! ```

use std::sync::Arc;

use hyca::coordinator::exp_fleet;
use hyca::fleet::{self, FleetEventKind};
use hyca::inference::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let engine = Arc::new(Engine::builtin());
    let cfg = exp_fleet::scenario_config(seed, false, workers);
    println!("== fleet configuration ==");
    println!(
        "chips {} (each {} with {} lanes) | policy {} | drain at {} live faults (re-admit below {})",
        cfg.chips.len(),
        cfg.chips[0].dims,
        cfg.chips[0].lanes,
        cfg.policy,
        cfg.lifecycle.drain_enter,
        cfg.lifecycle.drain_exit
    );
    println!(
        "clients {} | max_batch {} | requests {} | executor: {workers} worker threads",
        cfg.clients, cfg.max_batch, cfg.total_requests
    );

    let report = fleet::run(&engine, &cfg)?;

    println!("\n== run summary ==");
    println!(
        "served {} requests in {} batches ({} total kcycles): \
         {:.2} imgs/Mcycle, cluster p50 {} / p99 {} cycles",
        report.total_requests,
        report.batches,
        report.total_cycles / 1000,
        report.throughput_imgs_per_mcycle,
        report.p50_cycles(),
        report.p99_cycles()
    );
    println!(
        "availability {:.4} | drain episodes {} | unrepaired faults {}",
        report.availability(),
        report.drains(),
        report.unrepaired
    );

    println!("\n== cluster timeline ==");
    if report.events.is_empty() {
        println!("(no faults arrived this run — try another seed)");
    }
    for e in &report.events {
        match e.kind {
            FleetEventKind::FaultArrival(c) => println!(
                "  cycle {:>8}  chip {}: fault arrives at PE({},{})",
                e.cycle, e.chip, c.row, c.col
            ),
            FleetEventKind::ScanDetection(c) => println!(
                "  cycle {:>8}  chip {}: scan detects PE({},{}) → FPT insert → DPPU remap",
                e.cycle, e.chip, c.row, c.col
            ),
            FleetEventKind::Drained => println!(
                "  cycle {:>8}  chip {}: DRAINED (live faults ≥ {}) — traffic re-sharded",
                e.cycle, e.chip, cfg.lifecycle.drain_enter
            ),
            FleetEventKind::Readmitted => println!(
                "  cycle {:>8}  chip {}: RE-ADMITTED — router restores its share",
                e.cycle, e.chip
            ),
        }
    }

    println!("\n== per-chip breakdown ==");
    for c in &report.per_chip {
        let acc = match c.accuracy() {
            Some(a) => format!("{a:.4}"),
            None => "  -   ".to_string(),
        };
        println!(
            "  chip {}  {}  served {:>4}  acc {}  drains {}  drained {:>6} kcycles",
            c.chip,
            c.dims,
            c.requests,
            acc,
            c.drains,
            c.drained_cycles / 1000
        );
    }

    println!("\n== goodput / accuracy / availability over time ==");
    for w in &report.windows {
        let acc = match w.accuracy() {
            Some(a) => format!("{a:.4}"),
            None => "  -   ".to_string(),
        };
        let bar = match w.accuracy() {
            Some(a) => "#".repeat((a * 30.0).round() as usize),
            None => String::new(),
        };
        println!(
            "  [{:>8}, {:>8})  n={:<3} acc={acc} avail={:.3}  {bar}",
            w.start_cycle, w.end_cycle, w.requests, w.availability
        );
    }

    println!("\n== verdict ==");
    println!(
        "overall accuracy {:.4}; served {}/{} requests; unrepaired: {}",
        report.accuracy, report.total_requests, cfg.total_requests, report.unrepaired
    );
    if report.unrepaired == 0 && report.final_window_accuracy() == Some(1.0) {
        println!("full recovery: post-readmit fleet accuracy is exactly 1.0. ✔");
    } else {
        println!("no full recovery this run (over-capacity or undetected faults).");
    }
    println!("(benchmark grid + BENCH_fleet.json: `cargo run --release -- fleet`)");
    Ok(())
}
